"""End-to-end behaviour tests for the system.

Covers the LM substrate smoke (every assigned arch, reduced config: one
train step + prefill + decode with shape/NaN asserts) and learning on the
synthetic task.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_arch, reduced, shapes_for
from repro.launch.steps import (StepOptions, TrainState, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.nn import model as model_lib
from repro.nn.dims import compute_dims
from repro.optim.adamw import AdamW


def _batch(cfg, dims, b, s, key):
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    out = {"labels": toks[:, 1:]}
    if cfg.frontend == "text":
        out["tokens"] = toks[:, :-1]
    else:
        out["embeds"] = jax.random.normal(key, (b, s, dims.d_model),
                                          jnp.bfloat16)
    return out


@pytest.mark.parametrize("arch_id", all_archs())
def test_arch_smoke_train_and_serve(arch_id):
    """One reduced-config train step + prefill + decode per assigned arch."""
    cfg = reduced(get_arch(arch_id))
    dims = compute_dims(cfg, tp=1)
    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(cfg, dims, key)

    b, s = 2, 32
    batch = _batch(cfg, dims, b, s, key)

    opt = AdamW(lr=1e-3)
    state = TrainState(params, opt.init(params))
    step = jax.jit(make_train_step(cfg, dims, opt))
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch_id, loss)
    assert loss > 0.5, (arch_id, loss)       # CE over a >=256 vocab

    prefill = jax.jit(make_prefill_step(cfg, dims, s_max=s + 4))
    logits, cache = prefill(state.params, batch)
    assert logits.shape == (b, dims.vocab)
    assert not bool(jnp.isnan(logits).any())

    decode = jax.jit(make_decode_step(cfg, dims))
    tok = (jnp.zeros((b, 1), jnp.int32) if cfg.frontend == "text"
           else jax.random.normal(key, (b, 1, dims.d_model), jnp.bfloat16))
    logits2, cache = decode(state.params, cache, tok, jnp.int32(s))
    assert logits2.shape == (b, dims.vocab)
    assert not bool(jnp.isnan(logits2).any())


@pytest.mark.parametrize("arch_id", all_archs())
def test_shape_cells_defined(arch_id):
    cfg = get_arch(arch_id)
    names = {sh.name for sh in shapes_for(cfg)}
    assert {"train_4k", "prefill_32k", "decode_32k"} <= names
    if cfg.subquadratic:
        assert "long_500k" in names
    else:
        assert "long_500k" not in names


def test_training_reduces_loss():
    """A few steps on the synthetic copy task must actually learn."""
    from repro.data.pipeline import DataConfig, synthetic_batch
    from repro.configs.base import ShapeSpec
    cfg = reduced(get_arch("tinyllama-1.1b"))
    dims = compute_dims(cfg, tp=1)
    params = model_lib.init_params(cfg, dims, jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3)
    state = TrainState(params, opt.init(params))
    step = jax.jit(make_train_step(cfg, dims, opt))
    shape = ShapeSpec("tiny", 64, 8, "train")
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in
                 synthetic_batch(i, cfg, dims, shape, DataConfig()).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::10]


def test_microbatch_accumulation_matches_full_batch():
    cfg = reduced(get_arch("qwen1.5-0.5b"))
    dims = compute_dims(cfg, tp=1)
    key = jax.random.PRNGKey(3)
    params = model_lib.init_params(cfg, dims, key)
    batch = _batch(cfg, dims, 4, 32, key)
    opt = AdamW(lr=1e-3)

    s0 = TrainState(params, opt.init(params))
    full = jax.jit(make_train_step(cfg, dims, opt))
    s1, m1 = full(s0, batch)

    s0b = TrainState(params, opt.init(params))
    micro = jax.jit(make_train_step(cfg, dims, opt,
                                    StepOptions(microbatch=2)))
    s2, m2 = micro(s0b, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2
    # updated weights agree to accumulation tolerance
    l1 = jax.tree.leaves(s1.params)[0].astype(jnp.float32)
    l2 = jax.tree.leaves(s2.params)[0].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=5e-2, rtol=0.2)
