"""Orbit-aware radiation layer tests (DESIGN.md §16).

* ``RadiationEnvironment``: the periodic rate model (eclipse phase
  factors x SAA window), the NHPP thinning sampler (deterministic per
  seed, typed upset classes), and the numerical rate integral.
* MBU injection: ``flip_mbu`` corrupts exactly one bit in each of
  ``span`` adjacent bytes; byte-interleaved ECC domains make any burst
  of span <= n_domains single-byte-per-domain (correctable) where the
  contiguous layout is detect-only.
* Protection pricing: ECC +12.5% footprint + decode drag + scrub power,
  TMR 3x footprint + vote latency + tripled busy power — all flowing
  into ``CostSignature`` via ``protected_signature`` — and
  ``choose_protection``'s J/inf regime flip between the quiet orbit and
  an SAA pass.
* The controller under mixed storms (modeled clock): single/MBU/control
  upsets all detected + recovered with zero dropped requests; ECC
  corrects short bursts at injection and catches uncorrectable ones at
  the scrub; TMR masks everything; control-path structural checks
  restore the EWMA ladder, rebuild queue deadlines, and rewrite a
  corrupted tuning-cache file.
* Checkpoint-cadence optimization: the chosen cadence beats 10x finer
  and 10x coarser on expected replay-loss + overhead.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.core import energy, faults, memory, radiation
from repro.core.engine import Engine
from repro.core.scheduler import ContinuousBatchingScheduler, bursty_arrivals
from repro.models import SPACE_MODELS, synthetic_requests

MODEL = "multi_esperta"             # six int8 dense heads -> real arenas
BACKENDS = ("accel", "cpu")
LADDER = (1, 4)
N = 16


@pytest.fixture(scope="module")
def engines():
    m = SPACE_MODELS[MODEL]
    e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
    e.calibrate([m.synthetic_input(jax.random.PRNGKey(i)) for i in range(2)])
    return {MODEL: (m, e)}


@pytest.fixture()
def accel_plan(engines):
    _, e = engines[MODEL]
    plan = e.planned("accel")
    yield plan
    plan.repack_weights()


def _sched(engines, **kw):
    sched = ContinuousBatchingScheduler(clock="modeled", **kw)
    m, e = engines[MODEL]
    reqs = synthetic_requests(m, N, seed=5)
    sched.register(MODEL, e, backend=BACKENDS, ladder=LADDER,
                   warmup_sample=reqs[0])
    trace = [(t, MODEL, r) for t, r in
             zip(bursty_arrivals(N, burst_size=4, gap_s=0.01, seed=20),
                 reqs)]
    return sched, trace


def _controller(sched, engines, **cfg_kw):
    ctl = faults.FaultController(faults.FaultConfig(**cfg_kw))
    sched.attach_faults(ctl)
    m, _ = engines[MODEL]
    ctl.arm(sched, MODEL, synthetic_requests(m, 1, seed=5))
    return ctl


def _arena_pristine(plan) -> bool:
    return all(np.array_equal(np.asarray(plan.weight_arena[n]),
                              plan.host_weights[n])
               for n in plan.weight_arena)


# ---------------------------------------------------------------------------
# the environment
# ---------------------------------------------------------------------------


def test_orbit_geometry_and_rates():
    env = radiation.RadiationEnvironment()
    assert env.orbit_s == pytest.approx(0.5)
    assert env.phase_of(0.05) == "sunlight"
    assert env.phase_of(0.17) == "penumbra"
    assert env.phase_of(0.25) == "eclipse"
    assert env.phase_of(0.45) == "sunlight"
    assert env.phase_of(0.05 + 3 * env.orbit_s) == "sunlight"  # periodic
    assert env.in_saa(0.25) and not env.in_saa(0.05)
    assert env.in_saa(0.25 + env.orbit_s)
    # rate = base x phase factor x SAA multiplier
    assert env.rate(0.05) == pytest.approx(env.base_rate)
    assert env.rate(0.34) == pytest.approx(env.base_rate * 1.3)
    assert env.rate(0.25) == pytest.approx(env.base_rate * 1.3 * 40.0)
    # the thinning envelope is a TIGHT bound: reached inside the SAA pass
    grid = [env.rate(t) for t in np.linspace(0.0, env.orbit_s, 2001)]
    assert max(grid) <= env.rate_bound() + 1e-12
    assert max(grid) == pytest.approx(env.rate_bound())


def test_expected_upsets_matches_analytic_integral():
    env = radiation.RadiationEnvironment()
    # piecewise-constant rate: sum(dur x factor) + the SAA excess, which
    # sits entirely inside the eclipse phase (0.20-0.35 s)
    saa_w = env.saa_window[1] - env.saa_window[0]
    analytic = env.base_rate * (
        0.15 * 1.0 + 0.05 * 1.15 + 0.15 * 1.3 + 0.05 * 1.15 + 0.10 * 1.0
        + (env.saa_factor - 1.0) * 1.3 * saa_w)
    got = env.expected_upsets(0.0, env.orbit_s)
    assert got == pytest.approx(analytic, rel=1e-2)


def test_sample_upsets_deterministic_typed_sorted():
    env = radiation.RadiationEnvironment()
    a = env.sample_upsets(seed=3, horizon_s=2.0)
    assert a == env.sample_upsets(seed=3, horizon_s=2.0)
    assert a != env.sample_upsets(seed=4, horizon_s=2.0)
    ts = [ev.t for ev in a]
    assert ts == sorted(ts) and all(0.0 <= t < 2.0 for t in ts)
    kinds = {ev.kind for ev in a}
    assert kinds == {"single", "mbu", "control"}    # 4 orbits: all classes
    for ev in a:
        if ev.kind == "mbu":
            assert env.mbu_span[0] <= ev.span <= env.mbu_span[1]
        elif ev.kind == "control":
            assert ev.target in radiation.CONTROL_TARGETS
        else:
            assert ev.span == 1 and ev.target == ""
    assert env.sample_upsets(seed=3, horizon_s=0.0) == ()


def test_uncorrectable_fraction():
    env = radiation.RadiationEnvironment()            # mbu spans 2..8
    # 4 domains: spans 5..8 of the 7 equiprobable spans escape SEC
    mix = dict(env.mix)
    arena_w = mix["single"] + mix["mbu"]
    assert env.uncorrectable_fraction(4) == pytest.approx(
        mix["mbu"] * (4 / 7) / arena_w)
    assert env.uncorrectable_fraction(8) == 0.0
    assert env.uncorrectable_fraction(1) == pytest.approx(
        mix["mbu"] / arena_w)


def test_upset_event_validation():
    with pytest.raises(ValueError, match="kind"):
        radiation.UpsetEvent(0.0, kind="tripleplay")
    with pytest.raises(ValueError, match="span"):
        radiation.UpsetEvent(0.0, kind="mbu", span=0)
    with pytest.raises(ValueError, match="target"):
        radiation.UpsetEvent(0.0, kind="control", target="fpga")
    with pytest.raises(ValueError, match="saa_window"):
        radiation.RadiationEnvironment(saa_window=(0.4, 0.3))
    with pytest.raises(ValueError, match="sum to 1"):
        radiation.RadiationEnvironment(mix=(("single", 0.5),))


# ---------------------------------------------------------------------------
# MBU injection + ECC domain interleaving
# ---------------------------------------------------------------------------


def test_flip_mbu_pinned_burst_shape(accel_plan):
    node = max(accel_plan.weight_arena,
               key=lambda n: accel_plan.host_weights[n].nbytes)
    got = faults.SEUInjector(seed=0).flip_mbu(accel_plan, span=2,
                                              node=node, byte=1)
    assert got == (node, 1, 2)
    host = accel_plan.host_weights[node].view(np.uint8).reshape(-1)
    flipped = np.array(accel_plan.weight_arena[node]) \
        .view(np.uint8).reshape(-1)
    diff = host ^ flipped
    changed = np.nonzero(diff)[0]
    assert list(changed) == [1, 2]                  # exactly the burst
    for b in changed:
        assert bin(int(diff[b])).count("1") == 1    # one bit per byte


def test_flip_mbu_deterministic_and_clamped(accel_plan):
    inj = faults.SEUInjector(seed=9)
    a = inj.flip_mbu(accel_plan, span=5)
    accel_plan.repack_weights()
    b = faults.SEUInjector(seed=9).flip_mbu(accel_plan, span=5)
    assert a == b
    accel_plan.repack_weights()
    node = min(accel_plan.weight_arena,
               key=lambda n: accel_plan.host_weights[n].nbytes)
    nbytes = accel_plan.host_weights[node].nbytes
    _, byte, span = faults.SEUInjector(seed=0).flip_mbu(
        accel_plan, span=nbytes + 100, node=node)
    assert span == nbytes and byte == 0             # clamped to the entry


def test_protection_domain_interleaving():
    plan = memory.plan_protection_domains(1024, n_domains=4)
    assert plan.interleaved
    assert [plan.domain_of(b) for b in range(6)] == [0, 1, 2, 3, 0, 1]
    for span in range(1, 10):
        assert plan.worst_hit(span) == -(-span // 4)
    assert plan.correctable(1) and plan.correctable(4)
    assert not plan.correctable(5)
    assert max(plan.domains_hit(7, 4).values()) == 1
    # the naive contiguous layout: a burst lands inside ONE stripe
    naive = memory.plan_protection_domains(1024, 4, interleaved=False)
    assert naive.domain_of(0) == 0 and naive.domain_of(1023) == 3
    assert naive.worst_hit(4) == 4
    assert naive.correctable(1) and not naive.correctable(2)
    assert max(naive.domains_hit(8, 4).values()) == 4


def test_protected_weight_bytes():
    assert memory.protected_weight_bytes(1024, "none") == 1024
    assert memory.protected_weight_bytes(1024, "ecc") == 1152
    assert memory.protected_weight_bytes(1000, "ecc") == 1125
    assert memory.protected_weight_bytes(7, "ecc") == 8      # ceil
    assert memory.protected_weight_bytes(1024, "tmr") == 3072
    with pytest.raises(ValueError, match="protection mode"):
        memory.protected_weight_bytes(8, "parity")


# ---------------------------------------------------------------------------
# protection pricing
# ---------------------------------------------------------------------------


def test_protection_cost_pricing():
    hw = energy.BACKEND_HW["accel"]
    pb = 1 << 16
    none = energy.protection_cost(hw, pb, "none")
    assert none.protected_bytes == pb and none.scrub_energy_j == 0.0
    assert none.scrub_power_w == 0.0 and none.latency_factor == 1.0
    ecc = energy.protection_cost(hw, pb, "ecc", scrub_period_s=0.05)
    assert ecc.protected_bytes == (pb * 9 + 7) // 8
    bw = hw.stage_bw or hw.hbm_bw
    assert ecc.scrub_s == pytest.approx(ecc.protected_bytes / bw)
    assert ecc.scrub_energy_j == pytest.approx(
        hw.power_busy * ecc.scrub_s
        + ecc.protected_bytes * hw.ddr_pj_per_byte)
    assert ecc.scrub_power_w == pytest.approx(ecc.scrub_energy_j / 0.05)
    tmr = energy.protection_cost(hw, pb, "tmr")
    assert tmr.protected_bytes == 3 * pb and tmr.power_copies == 3
    assert tmr.latency_factor > ecc.latency_factor > 1.0


def test_protected_signature_repricing(engines):
    sched, _ = _sched(engines)
    svc = sched._svcs[MODEL]
    sig = svc.costs[("accel", LADDER[0])]
    hw = energy.BACKEND_HW["accel"]
    pb = 1 << 16
    assert energy.protected_signature(
        sig, hw, energy.protection_cost(hw, pb, "none")) is sig
    ecc = energy.protected_signature(
        sig, hw, energy.protection_cost(hw, pb, "ecc"))
    assert ecc.protection == "ecc"
    assert ecc.latency_s >= sig.latency_s * (1.0 + energy.ECC_LATENCY_OVERHEAD
                                             ) - 1e-15
    assert ecc.j_per_inference > sig.j_per_inference
    tmr = energy.protected_signature(
        sig, hw, energy.protection_cost(hw, pb, "tmr"))
    assert tmr.protection == "tmr"
    assert tmr.power_w == pytest.approx(hw.power_busy * 3)
    assert tmr.j_per_inference > ecc.j_per_inference
    assert tmr.energy_j == pytest.approx(
        tmr.power_w * tmr.latency_s + tmr.ddr_energy_j)


def test_apply_protection_swaps_signatures_and_reseeds(engines):
    sched, _ = _sched(engines)
    ctl = _controller(sched, engines, protection="ecc",
                      self_test_period=0.05)
    svc = sched._svcs[MODEL]
    assert svc.protection == "ecc"
    am = ctl._models[MODEL]
    assert am.protection_cost is not None and am.domains is not None
    arena_bytes = sum(int(np.asarray(a).nbytes)
                      for a in am.plan.weight_arena.values())
    assert am.domains.total_bytes == arena_bytes
    for r in LADDER:
        sig = svc.costs[("accel", r)]
        assert sig.protection == "ecc"
        # modeled clock serves on the protected timeline
        assert svc.est_service[("accel", r)] == sig.latency_s
    for r in LADDER:                    # fallback backend stays unprotected
        assert svc.costs[("cpu", r)].protection == "none"
    with pytest.raises(KeyError):
        sched.apply_protection(MODEL, "ecc",
                               {("accel", 999): svc.costs[("accel", 1)]})
    am.plan.repack_weights()


def test_choose_protection_flips_between_quiet_and_saa(engines):
    sched, _ = _sched(engines)
    ctl = _controller(sched, engines, self_test_period=0.05)
    svc = sched._svcs[MODEL]
    sig = svc.costs[("accel", LADDER[-1])]
    am = ctl._models[MODEL]
    # price a CNN-scale packed arena (~1 MiB int8, the paper's model
    # class) — multi_esperta's 18-byte toy arena makes every repack and
    # scrub free, which collapses the trade choose_protection models
    pb = 1 << 20
    env = radiation.RadiationEnvironment()
    p_unc = env.uncorrectable_fraction(4)
    quiet_best, quiet = faults.choose_protection(
        "accel", sig, pb, am.canary.cost, upset_rate=env.rate(0.05),
        p_uncorrectable=p_unc)
    saa_best, saa = faults.choose_protection(
        "accel", sig, pb, am.canary.cost, upset_rate=env.rate(0.25),
        p_uncorrectable=p_unc)
    for table in (quiet, saa):
        assert set(table) == set(energy.PROTECTION_MODES)
        assert all(np.isfinite(v) and v > 0 for v in table.values())
    # quiet orbit: the occasional canary undercuts any standing hardening;
    # an SAA pass: per-upset repack + exposure swamps it and ECC wins
    assert quiet_best == "none"
    assert saa_best == "ecc"
    assert saa["ecc"] < saa["none"] and saa["ecc"] < saa["tmr"]
    # TMR's standing power never beats ECC while bursts stay correctable
    assert quiet["none"] < quiet["ecc"] < quiet["tmr"]


def test_choose_protection_validation(engines):
    sched, _ = _sched(engines)
    ctl = _controller(sched, engines, self_test_period=0.05)
    sig = sched._svcs[MODEL].costs[("accel", 1)]
    cost = ctl._models[MODEL].canary.cost
    with pytest.raises(ValueError, match="self_test_period"):
        faults.choose_protection("accel", sig, 1024, cost, 1.0,
                                 self_test_period=0.0)
    with pytest.raises(ValueError, match="upset_rate"):
        faults.choose_protection("accel", sig, 1024, cost, -1.0)


# ---------------------------------------------------------------------------
# the controller under typed storms (modeled clock)
# ---------------------------------------------------------------------------


def test_mixed_storm_detected_recovered_zero_loss(engines):
    sched, trace = _sched(engines)
    upsets = (radiation.UpsetEvent(0.008),
              radiation.UpsetEvent(0.015, "mbu", span=6),
              radiation.UpsetEvent(0.022, "control", target="ladder"))
    ctl = _controller(sched, engines, upsets=upsets,
                      self_test_period=0.02)
    sched.serve_trace(trace)
    rep = ctl.report()
    assert rep["n_injected"] == 3
    assert rep["n_detected"] == 3 and rep["n_recovered"] == 3
    per = rep["per_class"]
    assert per["single"]["n_recovered"] == 1
    assert per["mbu"]["n_recovered"] == 1
    assert per["control"]["n_recovered"] == 1
    bound = 0.02 * (1 + ctl.config.aging_fraction) + 0.01
    for kind in ("single", "mbu"):
        assert per[kind]["max_detection_latency_s"] <= bound
    assert sorted(c.rid for c in sched.completions) == list(range(N))
    assert _arena_pristine(ctl._models[MODEL].plan)


def test_ecc_corrects_short_burst_at_injection(engines):
    sched, trace = _sched(engines)
    ctl = _controller(sched, engines, protection="ecc",
                      interleave_domains=4, self_test_period=0.05,
                      upsets=(radiation.UpsetEvent(0.005, "mbu", span=3),))
    sched.serve_trace(trace)
    (ev,) = ctl.report()["events"]
    assert ev["action"] == "ecc-correct"
    assert ev["detected_at"] == ev["t_injected"]    # corrected on access
    assert ctl.n_corrected == 1
    assert ctl.injector.n_flips == 0                # arena never touched
    assert _arena_pristine(ctl._models[MODEL].plan)
    assert ctl.n_scrubs > 0                         # background scrub ran
    assert sorted(c.rid for c in sched.completions) == list(range(N))


def test_ecc_uncorrectable_burst_caught_by_scrub(engines):
    sched, trace = _sched(engines)
    ctl = _controller(sched, engines, protection="ecc",
                      interleave_domains=4, scrub_period_s=0.03,
                      self_test_period=0.5,      # canary far out of band
                      upsets=(radiation.UpsetEvent(0.005, "mbu", span=8),))
    sched.serve_trace(trace)
    (ev,) = ctl.report()["events"]
    assert ev["action"] == "scrub+repack"           # span 8 > 4 domains
    assert ctl.injector.n_flips > 0                 # it really landed
    assert ev["span"] <= 8                          # clamped to the entry
    lat = ev["detected_at"] - ev["t_injected"]
    assert lat <= 0.03 + 0.01                       # within one scrub period
    assert _arena_pristine(ctl._models[MODEL].plan)
    assert sorted(c.rid for c in sched.completions) == list(range(N))


def test_tmr_masks_all_arena_upsets(engines):
    sched, trace = _sched(engines)
    ctl = _controller(sched, engines, protection="tmr",
                      self_test_period=0.05,
                      upsets=(radiation.UpsetEvent(0.004),
                              radiation.UpsetEvent(0.009, "mbu", span=8)))
    sched.serve_trace(trace)
    rep = ctl.report()
    assert [e["action"] for e in rep["events"]] == ["tmr-mask"] * 2
    assert ctl.n_corrected == 2 and ctl.injector.n_flips == 0
    assert rep["max_detection_latency_s"] == 0.0    # masked at injection
    assert _arena_pristine(ctl._models[MODEL].plan)
    assert sorted(c.rid for c in sched.completions) == list(range(N))


# ---------------------------------------------------------------------------
# control-path upsets + structural checks
# ---------------------------------------------------------------------------


def test_control_ladder_corruption_restored_from_shadow(engines):
    sched, _ = _sched(engines)
    ctl = _controller(sched, engines, self_test_period=0.05)
    svc = sched._svcs[MODEL]
    before = dict(svc.est_service)
    ctl._inject(sched, radiation.UpsetEvent(0.0, "control",
                                            target="ladder"))
    assert any(est > ctl._EST_BAND * svc.costs[k].latency_s
               for k, est in svc.est_service.items())
    now = ctl._control_check(sched, 0.001)
    assert now > 0.001                              # the sweep is priced
    assert svc.est_service == before
    (ev,) = ctl.events
    assert ev.action == "control-restore"
    assert ev.recovered_at is not None and ev.target == "ladder"
    assert ctl.n_control_checks == 1


def test_control_queue_deadline_rebuilt(engines):
    m, _ = engines[MODEL]
    sched, _ = _sched(engines)
    ctl = _controller(sched, engines, self_test_period=0.05)
    svc = sched._svcs[MODEL]
    reqs = synthetic_requests(m, 1, seed=5)
    sched.submit(MODEL, reqs[0], arrival=0.0)
    ctl._inject(sched, radiation.UpsetEvent(0.0, "control",
                                            target="queue"))
    assert svc.queue[0].deadline > 1e6
    ctl._control_check(sched, 0.001)
    assert svc.queue[0].deadline == pytest.approx(
        svc.queue[0].arrival + svc.deadline_s)
    (ev,) = ctl.events
    assert ev.action == "control-rebuild" and ev.target == "queue"
    svc.queue.clear()


def test_control_tuning_cache_rewritten(engines, tmp_path):
    from repro.core.autotune import TuningCache
    sched, _ = _sched(engines)
    ctl = _controller(sched, engines, self_test_period=0.05)
    path = str(tmp_path / "tuning.json")
    cache = TuningCache(path)
    cache.put("k1", {"block": [8, 8]})
    cache.save()
    ctl.attach_tuning_cache(cache)
    ctl._inject(sched, radiation.UpsetEvent(0.0, "control",
                                            target="tuning"))
    # force the corruption to be structural (a random bit flip can land
    # inside a value and stay valid JSON — then the check self-heals)
    with open(path, "w", encoding="utf-8") as f:
        f.write("{ not json at all")
    ctl._control_check(sched, 0.001)
    (ev,) = ctl.events
    assert ev.action == "control-rewrite" and ev.target == "tuning"
    with open(path, encoding="utf-8") as f:
        payload = json.load(f)
    assert payload["entries"]["k1"] == {"block": [8, 8]}


def test_control_fault_without_target_falls_back_to_ladder(engines):
    sched, _ = _sched(engines)
    ctl = _controller(sched, engines, self_test_period=0.05)
    # empty queue, no staged buffers, no tuning cache: every draw of the
    # untyped control target must still land somewhere real
    for i in range(4):
        ctl._inject(sched, radiation.UpsetEvent(float(i), "control"))
    assert len(ctl.events) == 4
    assert all(ev.target in radiation.CONTROL_TARGETS
               for ev in ctl.events)
    ctl._control_check(sched, 1.0)
    assert all(ev.recovered_at is not None for ev in ctl.events
               if ev.target != "staging")


def test_controller_state_dict_roundtrip(engines, tmp_path):
    sched, trace = _sched(engines)
    ctl = _controller(sched, engines,
                      upsets=(radiation.UpsetEvent(0.005),
                              radiation.UpsetEvent(0.3, "mbu", span=4)),
                      self_test_period=0.02)
    sched.serve_trace(trace, stop_at=0.05)
    state = ctl.state_dict()
    path = str(tmp_path / "ctl.npz")
    faults.save_checkpoint(path, state)
    loaded = faults.load_checkpoint(path)

    fresh, _ = _sched(engines)
    ctl2 = _controller(fresh, engines,
                       upsets=(radiation.UpsetEvent(0.005),
                               radiation.UpsetEvent(0.3, "mbu", span=4)),
                       self_test_period=0.02)
    ctl2.load_state_dict(loaded)
    assert ctl2.state_dict() == state
    assert [ev.t for ev in ctl2._pending] == [ev.t for ev in ctl._pending]
    assert ctl2.injector._rng.bit_generator.state == \
        ctl.injector._rng.bit_generator.state
    with pytest.raises(ValueError, match="version"):
        ctl2.load_state_dict({"version": 99})
    ctl._models[MODEL].plan.repack_weights()


# ---------------------------------------------------------------------------
# checkpoint-cadence optimization
# ---------------------------------------------------------------------------


def test_expected_replay_cost_shape():
    env = radiation.RadiationEnvironment()
    c = 1e-3
    with pytest.raises(ValueError, match="positive"):
        radiation.expected_replay_cost(env, 1.0, 0.0, c)
    with pytest.raises(ValueError, match="checkpoint_cost_s"):
        radiation.expected_replay_cost(env, 1.0, 0.1, -1.0)
    # overhead-dominated at tiny T, replay-dominated at huge T
    fine = radiation.expected_replay_cost(env, 1.0, 1e-4, c)
    coarse = radiation.expected_replay_cost(env, 1.0, 1.0, c)
    assert fine > 1e-4 / 1e-4 * c * 0.9             # ~ H/T checkpoints
    assert coarse > radiation.expected_replay_cost(env, 1.0, 0.01, c)
    assert fine > radiation.expected_replay_cost(env, 1.0, 0.01, c)


def test_optimize_cadence_beats_10x_finer_and_coarser():
    env = radiation.RadiationEnvironment()
    plan = radiation.optimize_cadence(env, horizon_s=1.0,
                                      checkpoint_cost_s=1e-3)
    assert 0.0 < plan.cadence_s <= 1.0
    assert plan.n_checkpoints == int(np.ceil(1.0 / plan.cadence_s))
    assert len(plan.curve) == 41
    assert plan.expected_cost_s == pytest.approx(
        radiation.expected_replay_cost(env, 1.0, plan.cadence_s, 1e-3))
    finer = radiation.expected_replay_cost(env, 1.0,
                                           plan.cadence_s / 10.0, 1e-3)
    coarser = radiation.expected_replay_cost(env, 1.0,
                                             plan.cadence_s * 10.0, 1e-3)
    assert plan.expected_cost_s < finer
    assert plan.expected_cost_s < coarser


def test_optimize_cadence_tracks_upset_rate():
    # a hotter environment wants MORE frequent checkpoints
    quiet = radiation.RadiationEnvironment(base_rate=0.5)
    hot = radiation.RadiationEnvironment(base_rate=50.0)
    tq = radiation.optimize_cadence(quiet, 1.0, 1e-3).cadence_s
    th = radiation.optimize_cadence(hot, 1.0, 1e-3).cadence_s
    assert th < tq
