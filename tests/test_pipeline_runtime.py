"""Async pipelined runtime (DESIGN.md §12): stage decomposition, the
overlap ledger, ticket lifecycle, and scheduler identity.

* Stage decomposition: every plan yields a positive stage chain whose
  longest stage is the signature's ``pipelined_latency_s``; resources
  come from {host, accel, flex, cpu}; the decomposition is deterministic.
* PipelineTimeline: per-resource intervals never overlap, the pipelined
  makespan never exceeds the serialized one (speedup >= 1), and the
  ledger is pure arithmetic (same inputs -> same report).
* Tickets: ``execute_batch_async().retire()`` is bit-identical to
  ``execute_batch``; retirement is idempotent, releases the staging
  slot, and the pool falls back to fresh allocation (never deadlocks)
  when over-subscribed.
* Scheduler identity: with ``clock="modeled"``, ``pipeline=True`` is
  dispatch-for-dispatch and bit-exact identical to ``pipeline=False``
  (which is the PR-5 synchronous path) — including under a power
  envelope — while the overlap ledger prices the pipelining.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.energy import (BACKEND_HW, PipelineTimeline, PowerEnvelope,
                               StageCost, steady_state_overlap)
from repro.core.engine import Engine
from repro.core.pipeline import ServingPipeline
from repro.core.scheduler import ContinuousBatchingScheduler, bursty_arrivals
from repro.models import SPACE_MODELS, synthetic_requests

MODELS = ("logistic_net", "multi_esperta")


@pytest.fixture(scope="module")
def engines():
    out = {}
    for name in MODELS:
        m = SPACE_MODELS[name]
        e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
        e.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                     for i in range(2)])
        out[name] = (m, e)
    return out


def _requests(m, n, seed=3):
    return synthetic_requests(m, n, seed=seed)


# ---------------------------------------------------------------------------
# stage decomposition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["cpu", "flex", "accel"])
@pytest.mark.parametrize("name", MODELS)
def test_stage_costs_invariants(name, backend, engines):
    _, e = engines[name]
    plan = e.planned("flex" if backend == "cpu" else backend)
    stages = plan.stage_costs(4, backend="cpu" if backend == "cpu" else None)
    assert stages
    assert all(s.seconds >= 0.0 for s in stages)
    assert all(s.resource in ("host_in", "host_out", "accel", "flex", "cpu")
               for s in stages)
    longest = max(s.seconds for s in stages)
    assert longest <= sum(s.seconds for s in stages)
    # deterministic: same decomposition on every call
    assert plan.stage_costs(
        4, backend="cpu" if backend == "cpu" else None) == stages


@pytest.mark.parametrize("backend", ["flex", "accel"])
@pytest.mark.parametrize("name", MODELS)
def test_pipelined_latency_is_longest_stage(name, backend, engines):
    _, e = engines[name]
    plan = e.planned(backend)
    sig = e.compile(backend, 4).cost
    stages = plan.stage_costs(4)
    assert sig.pipelined_latency_s == pytest.approx(
        max(s.seconds for s in stages))
    # the serial fields are untouched by the pipelined term
    base = plan.cost_signature(4)
    assert dataclasses.replace(
        sig, pipelined_latency_s=base.pipelined_latency_s) == base


def test_stage_costs_host_stages_use_staging_bw(engines):
    """FPGA backends model a host staging channel (stage_bw > 0), so
    stage_in covers the per-dispatch overhead PLUS the input bytes at the
    staging bandwidth — larger batches stage longer."""
    _, e = engines["logistic_net"]
    plan = e.planned("accel")
    hw = BACKEND_HW["accel"]
    assert hw.stage_bw > 0
    s4 = plan.stage_costs(4)[0]
    s16 = plan.stage_costs(16)[0]
    assert s4.name == "stage_in" and s4.resource == "host_in"
    assert s4.seconds > hw.overhead_s
    assert s16.seconds > s4.seconds


def test_steady_state_overlap_formula():
    stages = (StageCost("stage_in", "host", 2.0),
              StageCost("seg0/accel", "accel", 3.0),
              StageCost("readback", "host", 1.0))
    assert steady_state_overlap(stages) == pytest.approx(6.0 / 3.0)
    assert steady_state_overlap(()) == 1.0


# ---------------------------------------------------------------------------
# the overlap ledger
# ---------------------------------------------------------------------------


def _chain(a, b, c):
    return (StageCost("stage_in", "host_in", a),
            StageCost("seg0/accel", "accel", b),
            StageCost("readback", "host_out", c))


def test_timeline_overlaps_distinct_resources():
    tl = PipelineTimeline()
    for _ in range(8):
        tl.add(_chain(1.0, 1.0, 0.0), earliest=0.0)
    # steady state: one batch per longest stage; serial: 2.0 per batch
    assert tl.serial_span_s == pytest.approx(16.0)
    assert tl.span_s == pytest.approx(9.0)      # 2.0 fill + 7 x 1.0
    assert tl.speedup_x > 1.7
    rep = tl.report()
    assert rep["n_dispatches"] == 8
    assert 0.0 < rep["occupancy"]["accel"] <= 1.0


def test_timeline_per_resource_intervals_never_overlap():
    tl = PipelineTimeline()
    for i in range(6):
        tl.add(_chain(0.5, 1.5, 0.25), earliest=0.1 * i)
    by_res = {}
    for iv in tl.intervals:
        by_res.setdefault(iv.resource, []).append(iv)
    for ivs in by_res.values():
        ivs = sorted(ivs, key=lambda x: x.start)
        for a, b in zip(ivs, ivs[1:]):
            assert a.end <= b.start + 1e-12
    # stages of ONE dispatch are chained in order
    for d in range(6):
        mine = [iv for iv in tl.intervals if iv.dispatch == d]
        for a, b in zip(mine, mine[1:]):
            assert a.end <= b.start + 1e-12


def test_timeline_speedup_at_least_one_and_deterministic():
    def build():
        tl = PipelineTimeline()
        for i in range(5):
            tl.add(_chain(0.3 + 0.1 * i, 1.0, 0.1), earliest=0.2 * i)
        return tl.report()
    a, b = build(), build()
    assert a == b                               # pure arithmetic
    assert a["overlap_speedup_x"] >= 1.0
    assert a["pipelined_span_s"] <= a["serial_span_s"] + 1e-12


def test_timeline_respects_earliest_data_arrival():
    tl = PipelineTimeline()
    start, _ = tl.add(_chain(1.0, 1.0, 0.0), earliest=5.0)
    assert start == pytest.approx(5.0)          # no time travel


# ---------------------------------------------------------------------------
# ticket lifecycle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["flex", "accel"])
def test_async_ticket_matches_sync_execute(backend, engines):
    m, e = engines["multi_esperta"]
    reqs = _requests(m, 3)
    pipe = ServingPipeline(e, backend=backend, batch_size=4)
    ref = pipe.execute_batch(reqs, rng=jax.random.PRNGKey(5))
    ticket = pipe.execute_batch_async(reqs, rng=jax.random.PRNGKey(5))
    assert not ticket.retired
    res = ticket.retire()
    assert ticket.retired
    assert res.keep == ref.keep
    for k in ref.outputs:
        np.testing.assert_array_equal(res.outputs[k], ref.outputs[k])
    # idempotent: the same result object comes back
    assert ticket.retire() is res


def test_ticket_releases_slot_and_sync_drains(engines):
    m, e = engines["logistic_net"]
    reqs = _requests(m, 2)
    pipe = ServingPipeline(e, backend="flex", batch_size=2,
                           staging_buffers=2)
    assert pipe.arena.n_free == 2
    t1 = pipe.execute_batch_async(reqs)
    t2 = pipe.execute_batch_async(reqs)
    assert pipe.arena.n_free == 0               # both slots owned
    assert len(pipe._inflight) == 2
    t1.retire()
    assert pipe.arena.n_free == 1
    pipe.sync()                                 # telemetry barrier
    assert t2.retired and pipe.arena.n_free == 2
    assert not pipe._inflight


def test_pool_exhaustion_falls_back_to_fresh_allocation(engines):
    """Over-subscribing the slot pool must not deadlock or corrupt: the
    extra dispatch stages into a fresh allocation (counted), and results
    stay bit-identical."""
    m, e = engines["multi_esperta"]
    reqs = _requests(m, 2)
    pipe = ServingPipeline(e, backend="flex", batch_size=2,
                           staging_buffers=1)
    ref = pipe.execute_batch(reqs, rng=jax.random.PRNGKey(1))
    tickets = [pipe.execute_batch_async(reqs, rng=jax.random.PRNGKey(1))
               for _ in range(3)]
    assert pipe.arena.n_fallback == 2           # slots: 1 owned, 2 fresh
    for t in tickets:
        res = t.retire()
        for k in ref.outputs:
            np.testing.assert_array_equal(res.outputs[k], ref.outputs[k])


def test_run_pipelined_matches_serial_run(engines):
    m, e = engines["multi_esperta"]
    reqs = _requests(m, 11)                     # ragged tail
    pipe = ServingPipeline(e, backend="flex", batch_size=4,
                           keep_predicate=lambda out: any(
                               float(np.max(v)) > 0 for v in out.values()))
    serial = pipe.run(reqs, pipeline=False)
    pipelined = pipe.run(reqs, pipeline=True)
    assert pipelined.n_requests == serial.n_requests == 11
    assert pipelined.n_kept == serial.n_kept
    assert pipelined.fps > 0 and serial.fps > 0
    assert pipelined.phases.overlapped >= 0.0
    assert not pipe._inflight                   # stream-end flush retired all


# ---------------------------------------------------------------------------
# scheduler identity: pipeline=True == pipeline=False (modeled clock)
# ---------------------------------------------------------------------------


def _serve(engines, pipeline, envelope=None, staging_buffers=2, n=40):
    env = None
    if envelope:
        # the known-servable pressure envelope of the serving tests: the
        # peak cap excludes the DPU sometimes (flex fallback + deferrals)
        # but every dispatch stays admissible eventually
        env = PowerEnvelope(10.0, peak_w=3.0, window_s=0.01)
    sched = ContinuousBatchingScheduler(clock="modeled", pipeline=pipeline,
                                        staging_buffers=staging_buffers,
                                        envelope=env)
    trace = []
    for mi, name in enumerate(MODELS):
        m, e = engines[name]
        reqs = _requests(m, n, seed=11 + mi)
        backend = ("accel", "flex") if envelope else "flex"
        sched.register(name, e, backend=backend, ladder=(1, 4, 16),
                       warmup_sample=reqs[0])
        trace += [(t, name, r) for t, r in
                  zip(bursty_arrivals(n, burst_size=8, gap_s=0.02,
                                      seed=40 + mi), reqs)]
    end = sched.serve_trace(trace)
    return sched, end


@pytest.mark.parametrize("envelope", [False, True],
                         ids=["plain", "envelope"])
def test_pipelined_scheduler_identical_to_sync(envelope, engines):
    """The tentpole's zero-drift gate: same virtual end time, same
    dispatch records, same completions (ids, timestamps, rungs, keeps)
    and BIT-identical outputs, pipeline on vs off."""
    sync_sched, sync_end = _serve(engines, pipeline=False, envelope=envelope)
    pipe_sched, pipe_end = _serve(engines, pipeline=True, envelope=envelope)
    assert pipe_end == sync_end
    assert pipe_sched.dispatches == sync_sched.dispatches
    assert len(pipe_sched.completions) == len(sync_sched.completions)
    for a, b in zip(pipe_sched.completions, sync_sched.completions):
        assert (a.rid, a.model, a.kept, a.arrival, a.finished, a.rung,
                a.n_real, a.deadline) == \
               (b.rid, b.model, b.kept, b.arrival, b.finished, b.rung,
                b.n_real, b.deadline)
        for k in b.outputs:
            np.testing.assert_array_equal(a.outputs[k], b.outputs[k])
    # ...and only the pipelined run carries an overlap ledger
    assert sync_sched.overlap_report() is None
    rep = pipe_sched.overlap_report()
    assert rep["n_dispatches"] == len(pipe_sched.dispatches)
    assert rep["overlap_speedup_x"] >= 1.0
    assert rep["pipelined_span_s"] <= rep["serial_span_s"] + 1e-12


def test_pipelined_scheduler_caps_inflight_depth(engines):
    m, e = engines["logistic_net"]
    reqs = _requests(m, 24)
    sched = ContinuousBatchingScheduler(clock="modeled", pipeline=True,
                                        staging_buffers=2)
    sched.register("logistic_net", e, backend="flex", ladder=(1, 4),
                   warmup_sample=reqs[0])
    for i, r in enumerate(reqs):
        sched.submit("logistic_net", r, arrival=0.001 * i)
    now, depth_seen = 0.0, 0
    while sched.pending():
        rec = sched.step(now, force=True)
        assert rec is not None
        depth_seen = max(depth_seen, len(sched._inflight))
        assert len(sched._inflight) <= 2
        now += rec.service_time
    assert depth_seen == 2                      # it really pipelined
    sched.sync()
    assert len(sched.completions) == len(reqs)
    assert not sched._inflight


def test_pipelined_ewma_observed_at_retirement(engines):
    """measured clock + pipeline: estimates update when tickets RETIRE
    (dispatch->retirement span), not at the non-blocking dispatch."""
    m, e = engines["logistic_net"]
    reqs = _requests(m, 4)
    sched = ContinuousBatchingScheduler(pipeline=True, staging_buffers=4)
    sched.register("logistic_net", e, backend="flex", ladder=(4,),
                   warmup_sample=reqs[0])
    svc = sched._svcs["logistic_net"]
    est_before = dict(svc.est_service)
    for i, r in enumerate(reqs):
        sched.submit("logistic_net", r, arrival=0.001 * i)
    rec = sched.step(1.0, force=True)
    assert rec is not None
    assert len(sched._inflight) == 1
    assert svc.est_service == est_before        # nothing observed yet
    sched.sync()
    assert svc.est_service != est_before        # retirement observed
    # the dispatch record was rewritten to the true retired service
    assert sched.dispatches[-1].service_time >= rec.service_time


def test_pipelined_trace_keeps_plan_cache_cold(engines):
    """Pipelined serving must never re-trace: arena-slot staging reuses
    the same compiled executable for full and ragged batches."""
    m, e = engines["logistic_net"]
    reqs = _requests(m, 21)
    sched = ContinuousBatchingScheduler(clock="modeled", pipeline=True)
    sched.register("logistic_net", e, backend="flex", ladder=(1, 4, 16),
                   warmup_sample=reqs[0])
    before = e.planned("flex").n_traces
    sched.serve_trace([(0.002 * i, "logistic_net", r)
                       for i, r in enumerate(reqs)])
    assert e.planned("flex").n_traces == before
    assert len(sched.completions) == len(reqs)


def test_pipelined_async_wall_clock_mode_completes_everything(engines):
    import time as _time
    m, e = engines["logistic_net"]
    reqs = _requests(m, 13)
    sched = ContinuousBatchingScheduler(pipeline=True, staging_buffers=3)
    sched.register("logistic_net", e, backend="flex", ladder=(1, 4),
                   warmup_sample=reqs[0])
    sched.start(poll_s=0.0005)
    try:
        rids = [sched.submit("logistic_net", r) for r in reqs]
        _time.sleep(0.01)
    finally:
        sched.stop(drain=True)
    assert sorted(c.rid for c in sched.completions) == sorted(rids)


def test_pipelined_poison_request_requeued(engines):
    """Staging errors surface at dispatch in pipelined mode too, with the
    batch back at the queue head."""
    m, e = engines["logistic_net"]
    good = _requests(m, 2)
    bad = {"wrong_key": np.zeros((2, 2), np.float32)}
    sched = ContinuousBatchingScheduler(clock="modeled", pipeline=True)
    sched.register("logistic_net", e, backend="flex", ladder=(1, 4),
                   warmup_sample=good[0])
    with pytest.raises(Exception):
        sched.serve_trace([(0.0, "logistic_net", good[0]),
                           (0.001, "logistic_net", bad),
                           (0.002, "logistic_net", good[1])])
    sched.sync()
    assert len(sched.completions) + sched.pending() == 3
    svc = sched._svcs["logistic_net"]
    assert any(r.inputs is bad for r in svc.queue)


def test_staging_buffers_validated():
    with pytest.raises(ValueError, match="staging_buffers"):
        ContinuousBatchingScheduler(staging_buffers=0)
