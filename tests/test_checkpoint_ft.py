"""Checkpoint + fault-tolerance behaviour: atomic commit, async writes,
crash-resume, heartbeats, elastic re-mesh end-to-end."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (AsyncCheckpointer, cleanup,
                                         latest_step, restore, save)
from repro.runtime.fault_tolerance import HeartbeatTable, StepGuard


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "w": jax.random.normal(k, (4, 8), jnp.float32),
        "b": jax.random.normal(k, (8,), jnp.bfloat16),
        "step": jnp.int32(3),
        "nested": {"m": jax.random.normal(k, (2, 2))},
    }


def test_save_restore_roundtrip_exact(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t)
    back = restore(str(tmp_path), 7, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_uncommitted_checkpoint_ignored(tmp_path):
    save(str(tmp_path), 5, _tree())
    save(str(tmp_path), 10, _tree())
    # simulate a host dying mid-save at step 15: directory, no COMMITTED
    os.remove(os.path.join(str(tmp_path), "step_000000010", "COMMITTED"))
    assert latest_step(str(tmp_path)) == 5
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path), 10, _tree())
    cleanup(str(tmp_path), keep=3)
    assert not os.path.exists(os.path.join(str(tmp_path), "step_000000010"))
    assert latest_step(str(tmp_path)) == 5


def test_cleanup_keeps_newest(tmp_path):
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, _tree())
    cleanup(str(tmp_path), keep=2)
    assert latest_step(str(tmp_path)) == 5
    assert restore(str(tmp_path), 4, _tree()) is not None
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path), 3, _tree())


def test_async_checkpointer_durable_after_wait(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = _tree()
    ck.save(12, t)
    ck.wait()
    assert latest_step(str(tmp_path)) == 12
    back = restore(str(tmp_path), 12, jax.tree.map(jnp.zeros_like, t))
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(t["w"]))


def test_step_guard_crash_commits_then_resume(tmp_path):
    """The launcher's crash path: guard commits last-good state on failure,
    restart resumes from it and reaches the target step count."""
    def step_fn_factory(crash_at):
        def step_fn(state, batch):
            if crash_at is not None and int(state["n"]) + 1 == crash_at:
                raise RuntimeError("boom")
            return {"n": state["n"] + 1}, {"loss": jnp.float32(0)}
        return step_fn

    def batches():
        while True:
            yield {}

    ck = AsyncCheckpointer(str(tmp_path))
    guard = StepGuard(ck, save_every=4)
    state = {"n": jnp.int32(0)}
    with pytest.raises(RuntimeError):
        guard.run(state, step_fn_factory(7), batches(), 20)
    last = latest_step(str(tmp_path))
    assert last == 6                       # crashed entering step 7

    # restart: restore and run the remaining steps unharmed
    state = restore(str(tmp_path), last, {"n": jnp.int32(0)})
    assert int(state["n"]) == 6
    guard2 = StepGuard(AsyncCheckpointer(str(tmp_path)), save_every=4)
    state, end = guard2.run(state, step_fn_factory(None), batches(),
                            20 - last, start_step=last)
    assert int(state["n"]) == 20 and end == 20


def test_heartbeat_marks_dead_and_stays_dead():
    clock = {"t": 0.0}
    hb = HeartbeatTable(["a", "b", "c"], timeout_s=10.0,
                        clock=lambda: clock["t"])
    clock["t"] = 5.0
    hb.beat("a")
    hb.beat("b")
    clock["t"] = 12.0                      # c silent past the deadline
    assert hb.dead_hosts() == ["c"]
    assert hb.alive_hosts() == ["a", "b"]
    clock["t"] = 13.0
    hb.beat("c")                           # too late — dead stays dead
    assert hb.dead_hosts() == ["c"]


def test_elastic_remesh_after_pod_loss():
    """Losing a pod: 512 -> 256 chips keeps TP=16 and halves DP rows."""
    from repro.runtime.fault_tolerance import (elastic_mesh_shape,
                                               rebalance_batch)
    pods, data, model = elastic_mesh_shape(512, 16, pod_size=256)
    assert (pods, data, model) == (2, 16, 16)
    pods2, data2, model2 = elastic_mesh_shape(256, 16, pod_size=256)
    assert model2 == 16 and pods2 * data2 * model2 == 256
    nb = rebalance_batch(256, old_data=pods * data, new_data=pods2 * data2)
    assert nb == 128                       # per-replica batch preserved


def test_train_launcher_crash_resume_e2e(tmp_path):
    """Full launcher path (the train_driver example, compressed)."""
    from repro.launch import train as tl
    ckpt = str(tmp_path / "ck")
    os.environ["REPRO_CRASH_AT_STEP"] = "6"
    try:
        with pytest.raises(RuntimeError):
            tl.main(["--arch", "qwen1.5-0.5b", "--smoke", "--steps", "10",
                     "--batch", "2", "--seq", "16", "--ckpt-dir", ckpt,
                     "--save-every", "2", "--log-every", "100"])
    finally:
        os.environ.pop("REPRO_CRASH_AT_STEP", None)
    last = latest_step(ckpt)
    assert last is not None and last >= 4
    rc = tl.main(["--arch", "qwen1.5-0.5b", "--smoke", "--steps", "10",
                  "--batch", "2", "--seq", "16", "--ckpt-dir", ckpt,
                  "--save-every", "5", "--log-every", "100"])
    assert rc == 0
    assert latest_step(ckpt) >= 10
