"""Cross-backend conformance sweep: every space model x {cpu, flex,
accel} x batch rungs {1, 4, 16, 32} against the cpu (eager fp32)
reference — so backend-selection changes (now made at serve time by the
energy-aware dispatcher) can never silently change results.

The contract, per dtype and path:

* **integer outputs** (argmax region classes): EXACT on cpu/flex. On
  accel a class may flip ONLY where the fp32 logit margin is inside the
  pinned PTQ bound (each logit moves at most ``atol``, so a decisive
  margin — > 2x atol — can never flip), and only for a small fraction of
  samples: backend selection must never change a classification the
  fp32 path is decisive about.
* **flex** float outputs: float-associativity tolerance vs cpu (jitted
  vs eager fp32 reduce in different orders; measured <= ~1e-6).
* **accel** float outputs: within the model's pinned PTQ error bound vs
  cpu (static int8 scales; bounds measured on the fixed fixture and
  pinned with ~4x headroom — a plan/quantizer change that degrades PTQ
  fidelity fails here first). Thresholded *decision* outputs
  (ESPERTA's ``warn*``) are exempt from the cpu comparison — PTQ
  legitimately moves near-threshold warnings (the paper's "noticeable"
  PTQ note) — but they remain pinned by rung-invariance below.
* **int8 path rung-invariance**: on accel, rows of a batch-32 dispatch
  are BIT-identical to the batch-1/4/16 dispatches of the same requests
  whenever the plan is fully quantized (static scales + int32
  accumulation make the int8 kernels batch-shape-invariant); plans with
  fp32 matmul nodes on their flex tail get float-associativity
  tolerance instead.
"""
import os

import jax
import numpy as np
import pytest

from repro.core.engine import Engine
from repro.core.opgraph import base_op
from repro.models import SPACE_MODELS

# CONFORMANCE_TOP_RUNG caps the sweep (CI runs the conv-heavy models at
# a small rung so the full cross-backend contract still runs there; the
# uncapped 6x3x4 sweep is tier-1/slow)
_TOP_RUNG = int(os.environ.get("CONFORMANCE_TOP_RUNG", "32"))
RUNGS = tuple(r for r in (1, 4, 16, 32) if r <= _TOP_RUNG) or (1,)
TOP = RUNGS[-1]
BACKENDS = ("cpu", "flex", "accel")
N_CALIB = 4
INPUT_KEY, PARAM_KEY, RNG_KEY = 123, 0, 7

FLEX_TOL = dict(rtol=1e-5, atol=1e-5)
# per-model PTQ |output - cpu| bounds (measured max on this fixture:
# baseline 8.2e-3, cnet 8.5e-3, esperta 1.4e-1, logistic 0 [its dense is
# PTQ-demoted to flex], reduced 2.9e-3, vae 2.6e-2) pinned with headroom
ACCEL_ATOL = {
    "baseline_net": 0.05,
    "cnet_plus_scalar": 0.05,
    "multi_esperta": 0.3,
    "logistic_net": 1e-5,
    "reduced_net": 0.02,
    "vae_encoder": 0.1,
}


DECISION_OF = {"region": "head"}       # argmax output -> its logit tensor


def _is_decision(name: str, key: str) -> bool:
    return key.startswith("warn")


def _assert_flips_margin_bounded(got, ref, logits_ref, atol, msg):
    """Accel argmax flips are only legitimate on near-ties: every flipped
    row's fp32 top-1/top-2 margin must be within what the pinned PTQ
    logit perturbation can overcome, and flips must stay rare."""
    flipped = np.nonzero(got != ref)[0]
    assert flipped.size <= max(1, int(0.15 * got.size)), (
        f"{msg}: {flipped.size}/{got.size} PTQ decision flips")
    for i in flipped:
        top = np.sort(logits_ref[i].ravel())
        margin = float(top[-1] - top[-2])
        assert margin <= 2 * atol, (
            f"{msg}: row {i} flipped despite decisive fp32 margin "
            f"{margin:.3e} > 2*atol={2*atol:.3e}")


_STATE = {}


def _state(name):
    """Per-model engine + fixed fixture + memoized per-cell outputs (each
    of the 72 sweep cells is computed exactly once across the module)."""
    if name not in _STATE:
        m = SPACE_MODELS[name]
        e = Engine(m.build_graph(),
                   m.init_params(jax.random.PRNGKey(PARAM_KEY)))
        e.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                     for i in range(N_CALIB)])
        _STATE[name] = {
            "engine": e,
            "inputs": m.synthetic_batch(jax.random.PRNGKey(INPUT_KEY), TOP),
            "rngs": jax.random.split(jax.random.PRNGKey(RNG_KEY), TOP),
            "outs": {},
        }
    return _STATE[name]


def _outputs(name, backend, rung):
    st = _state(name)
    if (backend, rung) not in st["outs"]:
        out = st["engine"].run_batch(
            {k: v[:rung] for k, v in st["inputs"].items()},
            backend, st["rngs"][:rung])
        st["outs"][(backend, rung)] = {k: np.asarray(v)
                                       for k, v in out.items()}
    return st["outs"][(backend, rung)]


@pytest.mark.slow
@pytest.mark.parametrize("rung", RUNGS)
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(SPACE_MODELS))
def test_backend_matches_cpu_reference(name, backend, rung):
    ref = _outputs(name, "cpu", TOP)
    got = _outputs(name, backend, rung)
    assert set(got) == set(ref), (name, backend)
    for k in ref:
        a, r = got[k], ref[k][:rung]
        msg = f"{name}/{backend}/b{rung}/{k}"
        assert a.shape == r.shape, msg
        if np.issubdtype(a.dtype, np.integer):
            if backend == "accel" and k in DECISION_OF:
                _assert_flips_margin_bounded(
                    a, r, ref[DECISION_OF[k]][:rung], ACCEL_ATOL[name], msg)
            else:
                np.testing.assert_array_equal(a, r, err_msg=msg)
        elif backend == "accel":
            if _is_decision(name, k):
                continue                 # pinned by rung-invariance below
            np.testing.assert_allclose(a, r, rtol=1e-5,
                                       atol=ACCEL_ATOL[name], err_msg=msg)
        else:
            np.testing.assert_allclose(a, r, err_msg=msg, **FLEX_TOL)


@pytest.mark.parametrize("name", sorted(SPACE_MODELS))
def test_accel_rung_invariance(name):
    """Same requests through every accel rung: bit-exact for fully
    quantized plans, float-associativity otherwise — dispatch rung choice
    (including the envelope's rung degradation) cannot change results."""
    st = _state(name)
    plan = st["engine"].planned("accel")
    pure_int8 = not any(
        base_op(plan.graph.nodes[n]) in ("dense", "conv2d", "conv3d")
        for seg in plan.segments if seg.backend == "flex"
        for n in seg.nodes)
    top = _outputs(name, "accel", TOP)
    for rung in RUNGS[:-1]:
        small = _outputs(name, "accel", rung)
        for k in top:
            a, b = top[k][:rung], small[k]
            msg = f"{name}/accel b{TOP}[:{rung}] vs b{rung}/{k}"
            if pure_int8 or np.issubdtype(a.dtype, np.integer):
                np.testing.assert_array_equal(a, b, err_msg=msg)
            else:
                np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6,
                                           err_msg=msg)


@pytest.mark.parametrize("name", sorted(SPACE_MODELS))
def test_flex_rung_invariance(name):
    """Flex rows are rung-invariant to float associativity: the ladder
    and the scheduler's padding cannot perturb fp32 results."""
    top = _outputs(name, "flex", TOP)
    for rung in RUNGS[:-1]:
        small = _outputs(name, "flex", rung)
        for k in top:
            np.testing.assert_allclose(
                top[k][:rung], small[k], rtol=1e-6, atol=1e-6,
                err_msg=f"{name}/flex b{TOP}[:{rung}] vs b{rung}/{k}")


# ---------------------------------------------------------------------------
# fused vs unfused (the graph-compiler pass pipeline, DESIGN.md §10)
# ---------------------------------------------------------------------------

FUSED_RUNG = min(4, TOP)


@pytest.mark.parametrize("name", sorted(SPACE_MODELS))
def test_fused_matches_unfused(name):
    """The pass pipeline must be a pure optimization: fused plans are
    BIT-exact to the fuse=False escape hatch on both backends — int8
    because the monotone quantizer commutes with the fused chain ops,
    fp32 because fusion executes the identical op sequence inside one
    plan node (same XLA program)."""
    st = _state(name)
    m = SPACE_MODELS[name]
    e0 = Engine(m.build_graph(),
                m.init_params(jax.random.PRNGKey(PARAM_KEY)), fuse=False)
    e0.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                  for i in range(N_CALIB)])
    inputs = {k: v[:FUSED_RUNG] for k, v in st["inputs"].items()}
    rngs = st["rngs"][:FUSED_RUNG]
    for backend in ("flex", "accel"):
        fused = _outputs(name, backend, FUSED_RUNG)
        unfused = e0.run_batch(inputs, backend, rngs)
        for k in fused:
            np.testing.assert_array_equal(
                fused[k], np.asarray(unfused[k]),
                err_msg=f"{name}/{backend}/fused-vs-unfused/{k}")
    # the escape hatch reproduces the pre-pass plan node-for-node: no
    # rewritten nodes, segments covering the source graph exactly
    plan0 = e0.planned("accel")
    assert plan0.graph is e0.graph
    assert all(n.op not in ("fused", "const")
               for n in plan0.graph.nodes.values())
    flat = [n for seg in plan0.segments for n in seg.nodes]
    assert flat == [n for n in e0.graph.order
                    if e0.graph.nodes[n].op != "input"]
