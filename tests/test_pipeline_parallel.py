"""GPipe pipeline parallelism == sequential layer stack (subprocess: needs
8 virtual devices for a (data=2, stage=4) mesh)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.mark.slow
def test_pipeline_forward_matches_sequential():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, {src!r})
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel.pipeline_parallel import (bubble_fraction,
                                                      pipeline_forward)

        L, D, n_micro, mb, S = 8, 16, 6, 2, 4
        key = jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        params = {{
            "w": jax.random.normal(k1, (L, D, D)) * (D ** -0.5),
            "b": jax.random.normal(k2, (L, D)) * 0.1,
        }}
        x = jax.random.normal(k3, (n_micro, mb, S, D))

        def block_fn(lp, h):
            return jnp.tanh(h @ lp["w"] + lp["b"])

        # sequential reference
        def seq(h):
            for i in range(L):
                h = block_fn(jax.tree.map(lambda a: a[i], params), h)
            return h
        ref = jax.vmap(seq)(x)

        mesh = jax.make_mesh((2, 4), ("data", "stage"))
        got = jax.jit(lambda p, x: pipeline_forward(
            p, x, block_fn, mesh, extra_specs=P("data", None, None)))(params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
        assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
        print("pipeline == sequential OK")
    """).format(src=SRC)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=500)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
