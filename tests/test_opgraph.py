"""Shape-inference edge cases for the op-graph IR — previously only
exercised indirectly through the model builders: strided SAME conv, pools
on odd spatial dims (and stride != kernel), concat-axis validation, and
the accounting invariants the fused node kind must preserve.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.opgraph import Graph, Node, base_op, consumers, param_node


def _shape_of_exec(g, out, feed):
    """Execute the graph on the flex path and return out's shape — the
    ground truth the shape inference must match."""
    from repro.core.engine import Engine
    e = Engine(g, _params(g))
    res = e.run(feed, "flex")
    return tuple(np.asarray(res[out]).shape)


def _params(g):
    from repro.models.common import init_graph_params
    return init_graph_params(g, jax.random.PRNGKey(0))


@pytest.mark.parametrize("h,w,stride", [
    (13, 17, 2), (16, 16, 2), (7, 9, 3), (8, 8, 1),
])
def test_conv2d_same_stride_shape_matches_execution(h, w, stride):
    g = Graph("conv_same")
    x = g.input("x", (h, w, 3))
    c = g.add("conv2d", [x], name="c", kernel=(3, 3), features=4,
              stride=stride, padding="SAME")
    g.mark_output(c)
    want = (-(-h // stride), -(-w // stride), 4)
    assert g.nodes["c"].out_shape == want
    feed = {"x": np.zeros((h, w, 3), np.float32)}
    assert _shape_of_exec(g, c, feed) == want


@pytest.mark.parametrize("h,w,stride", [(13, 17, 2), (7, 7, 3)])
def test_conv2d_valid_stride_shape_matches_execution(h, w, stride):
    g = Graph("conv_valid")
    x = g.input("x", (h, w, 2))
    c = g.add("conv2d", [x], name="c", kernel=(3, 3), features=4,
              stride=stride, padding="VALID")
    g.mark_output(c)
    feed = {"x": np.zeros((h, w, 2), np.float32)}
    assert _shape_of_exec(g, c, feed) == g.nodes["c"].out_shape


@pytest.mark.parametrize("h,w,k,stride", [
    (7, 9, 2, 2),      # odd dims, kernel == stride
    (9, 7, 3, 2),      # stride != kernel (the old //stride formula broke)
    (8, 8, 3, 3),
    (5, 5, 2, 1),
])
def test_pool2d_shape_matches_execution(h, w, k, stride):
    g = Graph("pool")
    x = g.input("x", (h, w, 2))
    p = g.add("maxpool2d", [x], name="p", kernel=k, stride=stride)
    g.mark_output(p)
    want = ((h - k) // stride + 1, (w - k) // stride + 1, 2)
    assert g.nodes["p"].out_shape == want
    feed = {"x": np.zeros((h, w, 2), np.float32)}
    assert _shape_of_exec(g, p, feed) == want


def test_pool3d_odd_dims_shape_matches_execution():
    g = Graph("pool3")
    x = g.input("x", (7, 5, 9, 1))
    p = g.add("maxpool3d", [x], name="p", kernel=2)
    g.mark_output(p)
    assert g.nodes["p"].out_shape == (3, 2, 4, 1)
    feed = {"x": np.zeros((7, 5, 9, 1), np.float32)}
    assert _shape_of_exec(g, p, feed) == (3, 2, 4, 1)


def test_pool_kernel_larger_than_input_raises():
    g = Graph("pool_bad")
    x = g.input("x", (3, 3, 1))
    with pytest.raises(ValueError, match="pool kernel"):
        g.add("maxpool2d", [x], name="p", kernel=4)


def test_conv2d_wrong_rank_raises():
    g = Graph("conv_bad")
    x = g.input("x", (16, 16))
    with pytest.raises(ValueError, match="rank-3"):
        g.add("conv2d", [x], name="c", kernel=(3, 3), features=4)


# ---------------------------------------------------------------------------
# concat validation
# ---------------------------------------------------------------------------


def test_concat_axis_out_of_range_raises():
    g = Graph("cat")
    a = g.input("a", (4, 3))
    b = g.input("b", (4, 3))
    with pytest.raises(ValueError, match="axis 2 out of range"):
        g.add("concat", [a, b], name="c", axis=2)


def test_concat_rank_mismatch_raises():
    g = Graph("cat2")
    a = g.input("a", (4, 3))
    b = g.input("b", (12,))
    with pytest.raises(ValueError, match="ranks differ"):
        g.add("concat", [a, b], name="c", axis=0)


def test_concat_non_axis_dim_mismatch_raises():
    g = Graph("cat3")
    a = g.input("a", (4, 3))
    b = g.input("b", (5, 3))
    with pytest.raises(ValueError, match="non-axis dims differ"):
        g.add("concat", [a, b], name="c", axis=1)


def test_concat_negative_axis_infers_shape():
    g = Graph("cat4")
    a = g.input("a", (4, 3))
    b = g.input("b", (4, 5))
    c = g.add("concat", [a, b], name="c", axis=-1)
    assert g.nodes["c"].out_shape == (4, 8)


# ---------------------------------------------------------------------------
# fused / const node kinds + helpers
# ---------------------------------------------------------------------------


def test_fused_node_inference_delegates_to_base():
    g = Graph("fused_infer")
    x = g.input("x", (8, 8, 2))
    c = g.add("conv2d", [x], name="c", kernel=(3, 3), features=4)
    fused = Node("f", "fused", ["x"],
                 {"base_op": "conv2d", "kernel": (3, 3), "features": 4,
                  "epilogue": ("relu",), "param_of": "c"})
    from repro.core.opgraph import _infer
    _infer(fused, [g.nodes["x"]])
    ref = g.nodes["c"]
    assert fused.out_shape == ref.out_shape
    assert fused.param_count == ref.param_count
    assert fused.bias_params == ref.bias_params
    assert fused.macs == ref.macs
    assert fused.ops == ref.ops + int(np.prod(ref.out_shape))  # + relu
    assert base_op(fused) == "conv2d"
    assert param_node(fused) == "c"


def test_const_node_shape_and_accounting():
    g = Graph("const")
    c = g.add("const", [], name="k",
              value=np.zeros((3, 2), np.float32))
    assert g.nodes["k"].out_shape == (3, 2)
    assert g.nodes["k"].ops == 0 and g.nodes["k"].param_count == 0


def test_param_bytes_per_node_dtype():
    g = Graph("pb")
    x = g.input("x", (10,))
    d = g.add("dense", [x], name="d", features=4)      # 10*4 w + 4 b
    g.mark_output(d)
    assert g.param_bytes() == 44 * 4
    # int8 weights + fp32 bias
    assert g.param_bytes(node_dtype_bytes={"d": 1}) == 40 + 4 * 4
    # nodes absent from the map stay at the default width
    assert g.param_bytes(node_dtype_bytes={}) == 44 * 4


def test_consumers_helper():
    g = Graph("cons")
    x = g.input("x", (4,))
    a = g.add("relu", [x], name="a")
    b = g.add("exp", [a], name="b")
    c = g.add("add", [a, b], name="c")
    g.mark_output(c)
    cons = consumers(g)
    assert cons["a"] == ["b", "c"]
    assert cons["c"] == []


# ---------------------------------------------------------------------------
# auto-naming + error context (bugs flushed out by the jaxpr front-end)
# ---------------------------------------------------------------------------


def test_auto_name_skips_explicitly_named_collision():
    """Regression: auto-naming used f"{op}_{len(order)}" verbatim, so an
    explicitly-named node sitting at the next counter value made the
    following auto-named add raise 'duplicate node'."""
    g = Graph("names")
    x = g.input("x", (4,))                      # order: [x]
    g.add("relu", [x], name="relu_2")           # occupies the next auto slot
    got = g.add("relu", [x])                    # pre-fix: duplicate node
    assert got != "relu_2" and got in g.nodes
    assert g.nodes[got].op == "relu"


def test_auto_name_still_sequential_without_collisions():
    g = Graph("names2")
    x = g.input("x", (4,))
    assert g.add("relu", [x]) == "relu_1"
    assert g.add("exp", ["relu_1"]) == "exp_2"


def test_explicit_duplicate_name_still_raises():
    g = Graph("names3")
    x = g.input("x", (4,))
    g.add("relu", [x], name="a")
    with pytest.raises(ValueError, match="duplicate node"):
        g.add("exp", [x], name="a")


def test_infer_error_names_node_and_input_shapes():
    """Regression: shape-inference failures must carry the node name and
    its input shapes — a traced 200-eqn jaxpr dying with just 'rank-3'
    is undebuggable."""
    g = Graph("err")
    x = g.input("x", (16, 16))
    with pytest.raises(ValueError) as exc:
        g.add("conv2d", [x], name="my_conv", kernel=(3, 3), features=4)
    msg = str(exc.value)
    assert "my_conv" in msg
    assert "(16, 16)" in msg


def test_infer_wraps_missing_attr_as_named_valueerror():
    """A KeyError from a missing attr surfaces as a ValueError naming the
    node, not a bare KeyError: 'kernel'."""
    g = Graph("err2")
    x = g.input("x", (8, 8, 2))
    with pytest.raises(ValueError) as exc:
        g.add("conv2d", [x], name="noattr", features=4)   # no kernel
    msg = str(exc.value)
    assert "noattr" in msg and "KeyError" in msg and "kernel" in msg
    assert "(8, 8, 2)" in msg


def test_infer_error_context_preserved_across_ops():
    g = Graph("err3")
    a = g.input("a", (4, 3))
    b = g.input("b", (5, 3))
    with pytest.raises(ValueError) as exc:
        g.add("concat", [a, b], name="bad_cat", axis=1)
    assert "bad_cat" in str(exc.value)


# ---------------------------------------------------------------------------
# grouped (depthwise) conv2d
# ---------------------------------------------------------------------------


def test_grouped_conv2d_shape_params_and_execution():
    g = Graph("dw")
    x = g.input("x", (8, 8, 6))
    c = g.add("conv2d", [x], name="dw", kernel=(3, 3), features=6,
              stride=1, padding="SAME", groups=6)
    g.mark_output(c)
    node = g.nodes["dw"]
    assert node.out_shape == (8, 8, 6)
    assert node.param_count == 3 * 3 * 1 * 6 + 6       # cin/groups == 1
    assert node.macs == 8 * 8 * 6 * 3 * 3 * 1
    feed = {"x": np.random.default_rng(0).normal(
        size=(8, 8, 6)).astype(np.float32)}
    assert _shape_of_exec(g, c, feed) == (8, 8, 6)


def test_grouped_conv2d_matches_per_channel_reference():
    """Depthwise conv == per-channel 2-D correlation; checks the groups
    plumbing end to end (shape inference -> param init -> impl)."""
    g = Graph("dwref")
    x = g.input("x", (5, 5, 3))
    c = g.add("conv2d", [x], name="dw", kernel=(3, 3), features=3,
              stride=1, padding="VALID", groups=3)
    g.mark_output(c)
    params = _params(g)
    assert params["dw"]["w"].shape == (3, 3, 1, 3)
    from repro.core.engine import Engine
    feed = {"x": np.random.default_rng(1).normal(
        size=(5, 5, 3)).astype(np.float32)}
    out = np.asarray(Engine(g, params).run(feed, "flex")["dw"])
    w = np.asarray(params["dw"]["w"])
    for ch in range(3):
        ref = np.zeros((3, 3), np.float32)
        for i in range(3):
            for j in range(3):
                ref[i, j] = np.sum(feed["x"][i:i + 3, j:j + 3, ch]
                                   * w[:, :, 0, ch])
        np.testing.assert_allclose(out[:, :, ch], ref, rtol=1e-5)


def test_grouped_conv2d_invalid_groups_raises():
    g = Graph("dwbad")
    x = g.input("x", (8, 8, 6))
    with pytest.raises(ValueError, match="groups=4"):
        g.add("conv2d", [x], name="dw", kernel=(3, 3), features=6,
              groups=4)
