"""Dual-backend engine + the six space models: the paper's core claims as
assertions.

* Table I parameter/op counts within calibration tolerance.
* flex == cpu at fp32 fidelity (the paper's <=1e-10 HLS property — same
  math, jit on/off, so the bound here is float associativity ~1e-5).
* accel (INT8 PTQ + Pallas) close to flex within PTQ tolerance; PTQ error
  is nonzero (the paper's 'noticeable degradation').
* inspector routes exactly the ops the paper calls out (sigmoid/greater ->
  flex for ESPERTA, 3-D layers -> flex for MMS, sampling tail -> flex for
  the VAE, CNet fully accel).
* multi-ESPERTA parallel == six sequential ESPERTA models.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import inspector
from repro.core.engine import Engine
from repro.models import SPACE_MODELS

TABLE1_TOL = {"params": 0.01, "ops": 0.25}


@pytest.fixture(scope="module")
def engines():
    out = {}
    for name, m in SPACE_MODELS.items():
        g = m.build_graph()
        e = Engine(g, m.init_params(jax.random.PRNGKey(0)))
        e.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                     for i in range(2)])
        out[name] = (m, g, e)
    return out


@pytest.mark.parametrize("name", sorted(SPACE_MODELS))
def test_table1_counts(name):
    m = SPACE_MODELS[name]
    g = m.build_graph()
    assert abs(g.n_params - m.paper_params) <= max(
        TABLE1_TOL["params"] * m.paper_params, 1), (g.n_params, m.paper_params)
    assert abs(g.n_ops - m.paper_ops) <= max(
        TABLE1_TOL["ops"] * m.paper_ops, 20), (g.n_ops, m.paper_ops)


@pytest.mark.parametrize("name", sorted(SPACE_MODELS))
def test_flex_matches_cpu(name, engines):
    m, g, e = engines[name]
    inputs = m.synthetic_input(jax.random.PRNGKey(3))
    rng = jax.random.PRNGKey(0)
    a = e.run(inputs, "cpu", rng)
    b = e.run(inputs, "flex", rng)
    for k in a:
        np.testing.assert_allclose(
            np.asarray(a[k], np.float32), np.asarray(b[k], np.float32),
            rtol=1e-4, atol=1e-4), (name, k)


@pytest.mark.parametrize("name", sorted(SPACE_MODELS))
def test_accel_close_to_flex(name, engines):
    m, g, e = engines[name]
    inputs = m.synthetic_input(jax.random.PRNGKey(4))
    rng = jax.random.PRNGKey(0)
    a = e.run(inputs, "flex", rng)
    b = e.run(inputs, "accel", rng)
    for k in a:
        if a[k].dtype in (jnp.int32, jnp.int64):
            continue                      # argmax class may flip at margins
        ref = np.asarray(a[k], np.float32)
        got = np.asarray(b[k], np.float32)
        scale = max(1e-3, float(np.abs(ref).max()))
        assert np.abs(ref - got).max() <= 0.15 * scale, (name, k)


EXPECTED_FLEX_OPS = {
    "vae_encoder": {"sample_normal"},
    "cnet_plus_scalar": set(),
    "multi_esperta": {"sigmoid", "greater"},
    "logistic_net": {"maxpool3d", "argmax"},
    "reduced_net": {"conv3d", "maxpool3d", "argmax"},
    "baseline_net": {"conv3d", "maxpool3d", "argmax"},
}


@pytest.mark.parametrize("name", sorted(SPACE_MODELS))
def test_inspector_routing_matches_paper(name):
    g = SPACE_MODELS[name].build_graph()
    rep = inspector.inspect(g)
    got = set(rep.unsupported)
    want = EXPECTED_FLEX_OPS[name]
    assert want <= got, (name, want, got)
    extra = got - want - {"mul", "add", "sub", "concat", "exp",
                          "avgpool3d", "flatten", "tanh", "softplus"}
    assert not extra, (name, extra)
    if name == "cnet_plus_scalar":
        assert rep.fully_supported        # the paper runs it fully on the DPU


def test_multi_esperta_equals_six_sequential():
    from repro.models import esperta
    g = esperta.build_graph()
    e = Engine(g, esperta.init_params())
    x = esperta.synthetic_input(jax.random.PRNGKey(1))
    out = e.run(x, "flex")
    seq = esperta.sequential_reference(x)
    for k, v in seq.items():
        np.testing.assert_allclose(np.asarray(out[k]).ravel(),
                                   np.asarray(v).ravel(),
                                   rtol=1e-5, atol=1e-6)


def test_vae_compression_ratio():
    """128x256 RGB -> 6 floats is the paper's 1:16,384."""
    from repro.models import vae_encoder
    h, w, c = vae_encoder.INPUT_SHAPE
    assert h * w * c / vae_encoder.LATENT == 16384.0


def test_engine_partition_coverage():
    """MoE-style partial graphs: coverage weights accel MACs correctly."""
    for name, want_full in [("cnet_plus_scalar", True),
                            ("baseline_net", False)]:
        m = SPACE_MODELS[name]
        g = m.build_graph()
        e = Engine(g, m.init_params(jax.random.PRNGKey(0)))
        plan = e.plan()
        if want_full:
            assert plan.coverage == 1.0
        else:
            assert plan.coverage < 0.5        # 3-D convs dominate MMS MACs
