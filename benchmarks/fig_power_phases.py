"""Paper Figs 9-13 — power-over-time phase decomposition.

Modeled (no power rails on this host): the serving pipeline's phases —
idle, accelerator-program load (the bitstream-download spike of Fig 13;
on TPU this is the program + weight upload), input staging, inference,
idle — with per-phase power from the hardware model. Reported as an
ASCII timeline + per-phase energy split per space model.
"""
from __future__ import annotations

import numpy as np

from repro.core.energy import (TPU_V5E, ZCU104_DPU, ZCU104_HLS_NAIVE,
                               power_trace)
from repro.models import SPACE_MODELS

BARS = " ▁▂▃▄▅▆▇█"


def sparkline(w: np.ndarray, width: int = 64) -> str:
    idx = np.linspace(0, len(w) - 1, width).astype(int)
    s = w[idx]
    lo, hi = float(s.min()), float(s.max())
    if hi == lo:
        return BARS[1] * width
    q = ((s - lo) / (hi - lo) * (len(BARS) - 1)).astype(int)
    return "".join(BARS[i] for i in q)


def main() -> None:
    print("== Figs 9-13 analog: modeled power phases (1000 inferences) ==")
    for name, m in SPACE_MODELS.items():
        g = m.build_graph()
        hw = ZCU104_DPU if m.paper_toolchain == "vitis_ai" else ZCU104_HLS_NAIVE
        n = 10 if name == "baseline_net" else 1000   # paper uses 10 for BaselineNet
        t, w = power_trace(g, hw, "accel" if m.paper_toolchain == "vitis_ai"
                           else "flex", n_inferences=n)
        e = float(np.trapezoid(w, t))
        print(f"\n{name} ({hw.name}, {n} inferences)")
        print(f"  {sparkline(w)}")
        print(f"  span {t[-1]:.2f}s  peak {w.max():.2f}W  min {w.min():.2f}W  "
              f"E_total {e:.2f}J")
        # TPU-modeled comparison
        t2, w2 = power_trace(g, TPU_V5E, "accel", n_inferences=n)
        e2 = float(np.trapezoid(w2, t2))
        print(f"  tpu_v5e modeled: span {t2[-1]:.2f}s  peak {w2.max():.0f}W  "
              f"E_total {e2:.1f}J")


if __name__ == "__main__":
    main()
