"""Serving-load benchmark — the continuous-batching scheduler under
Poisson and bursty arrival traces -> BENCH_serving.json.

Drives two co-served space models through one scheduler per trace shape
and records per-model telemetry: p50/p99 latency against the use case's
deadline, achieved fps, batch-fill per ladder rung, deadline misses, and
the selective-downlink reduction. The virtual-clock trace makes the run
deterministic up to measured kernel service times.

Integrity is checked on every run (the acceptance gate for the bursty
regime): every submitted request completes exactly once — no drops, no
duplicates.

    PYTHONPATH=src python -m benchmarks.serving_load            # full
    PYTHONPATH=src python -m benchmarks.serving_load --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

import jax
import numpy as np

from repro.core.engine import Engine
from repro.core.scheduler import (ContinuousBatchingScheduler, DEFAULT_LADDER,
                                  bursty_arrivals, poisson_arrivals)
from repro.launch.serve import KEEP_PREDICATES
from repro.models import SPACE_MODELS, synthetic_requests

OUT_PATH = "BENCH_serving.json"
MODELS = ("logistic_net", "multi_esperta")
LADDER = DEFAULT_LADDER


def _requests(name: str, n: int, seed: int) -> List[Dict]:
    return synthetic_requests(SPACE_MODELS[name], n, seed=seed)


def _traces(kind: str, n: int, rate: float, seed: int) -> List[float]:
    if kind == "poisson":
        return poisson_arrivals(rate, n, seed=seed)
    # bursty: the instrument dumps half a ladder-top of samples at once,
    # with inter-burst gaps sized to the same mean rate
    burst = LADDER[-1] // 2
    return bursty_arrivals(n, burst_size=burst, gap_s=burst / rate,
                           seed=seed)


def run_trace(kind: str, backend: str, n_per_model: int, rate: float,
              engines: Dict[str, Engine], warmups: Dict[str, Dict]
              ) -> List[Dict]:
    sched = ContinuousBatchingScheduler()
    trace = []
    for mi, name in enumerate(MODELS):
        sched.register(name, engines[name], backend=backend, ladder=LADDER,
                       keep_predicate=KEEP_PREDICATES.get(name),
                       warmup_sample=warmups[name])
        reqs = _requests(name, n_per_model, seed=10 + mi)
        trace += [(t, name, r) for t, r in
                  zip(_traces(kind, n_per_model, rate, seed=20 + mi), reqs)]
    end = sched.serve_trace(trace)

    # integrity: every submitted request completed exactly once
    rids = [c.rid for c in sched.completions]
    n_dropped = len(trace) - len(set(rids))
    n_duplicated = len(rids) - len(set(rids))
    assert n_dropped == 0 and n_duplicated == 0, (n_dropped, n_duplicated)

    rows = []
    for name, tel in sched.telemetry().items():
        row = tel.to_dict()
        row.update(trace_kind=kind, backend=backend, rate_hz=rate,
                   virtual_end_s=end, n_dropped=n_dropped,
                   n_duplicated=n_duplicated,
                   p99_under_deadline=tel.p99_latency_ms
                   < tel.deadline_s * 1e3)
        rows.append(row)
        print(f"  [{kind}/{backend}] {name}: p50={tel.p50_latency_ms:.2f} ms "
              f"p99={tel.p99_latency_ms:.2f} ms "
              f"(deadline {tel.deadline_s*1e3:.0f} ms, "
              f"{tel.deadline_misses} missed)  fps={tel.fps:.0f}  "
              f"fill={tel.mean_batch_fill:.0%}  "
              f"downlink -{tel.downlink_reduction:.0%}")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small request counts for CI")
    # a full top-rung batch (32) should fill well inside the tightest
    # deadline window (150 ms): 384 req/s fills one in ~83 ms
    ap.add_argument("--rate", type=float, default=384.0,
                    help="per-model mean arrival rate (req/s)")
    ap.add_argument("--backends", default="flex",
                    help="comma list of backends to sweep")
    args = ap.parse_args(argv)
    n = 64 if args.smoke else 256

    print(f"== serving load: {', '.join(MODELS)} x "
          f"{{poisson, bursty}} @ {args.rate:.0f} req/s each ==")
    rows: List[Dict] = []
    for backend in args.backends.split(","):
        engines, warmups = {}, {}
        for name in MODELS:
            m = SPACE_MODELS[name]
            engines[name] = Engine(m.build_graph(),
                                   m.init_params(jax.random.PRNGKey(0)))
            warmups[name] = _requests(name, 1, seed=99)[0]
            if backend == "accel":
                engines[name].calibrate(_requests(name, 4, seed=98))
        for kind in ("poisson", "bursty"):
            rows += run_trace(kind, backend, n, args.rate, engines, warmups)

    with open(OUT_PATH, "w") as f:
        json.dump({"n_per_model": n, "ladder": list(LADDER),
                   "rows": rows}, f, indent=1)
    print(f"[serving_load] wrote {len(rows)} rows -> {OUT_PATH}")

    poisson_flex = [r for r in rows
                    if r["trace_kind"] == "poisson" and r["backend"] == "flex"]
    ok_fill = all(r["mean_batch_fill"] > 0.5 for r in poisson_flex)
    ok_p99 = all(r["p99_under_deadline"] for r in poisson_flex)
    print(f"[gate] poisson/flex batch-fill>50%: {ok_fill}  "
          f"p99<deadline: {ok_p99}")
    if args.smoke:
        # CI runners have unpredictable speed; wall-clock p99 vs a mission
        # deadline is a host property, not a code property — smoke gates
        # only on the machine-independent invariants (fill; the no-drop /
        # no-dup assert above).
        return 0 if ok_fill else 1
    return 0 if (ok_fill and ok_p99) else 1


if __name__ == "__main__":
    raise SystemExit(main())
