"""Fusion benchmark — the graph-compiler pass pipeline's wins, gated
-> BENCH_fusion.json.

Three parts:

1. **Plan table** (machine-independent): for every space model, the
   fused plan's modeled DDR bytes and J/inference at the serving rung vs
   the fuse=False op-by-op plan, on the accel path. Gates: fusion
   REDUCES both for the conv-heavy models (CNet, VAE) — the paper's
   HLS-streaming-vs-op-by-op-DPU lever, now expressed by our own plans.
2. **Conformance spot-check** (machine-independent): fused and unfused
   plans produce bit-identical outputs for the gated models on accel.
3. **Wall-clock** (host-dependent, skipped in --smoke): fused flex
   throughput at batch 32 must not regress vs unfused (the pass
   pipeline must never make the jitted path slower — XLA already fused
   these ops; the plan-level fusion must be free).

    PYTHONPATH=src python -m benchmarks.fusion            # full
    PYTHONPATH=src python -m benchmarks.fusion --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro.core.engine import Engine
from repro.models import SPACE_MODELS

OUT_PATH = "BENCH_fusion.json"
SERVE_RUNG = 32
GATED_MODELS = ("cnet_plus_scalar", "vae_encoder")   # conv-heavy
N_CALIB = 4
WALL_BATCH = 32
WALL_REPEATS = 3
# the jitted program is identical; allow generous timer noise headroom
WALL_TOLERANCE = 0.85


_ENGINES = {}


def _engines(name: str):
    """(model, fused engine, unfused engine) — memoized: PTQ calibration
    drives the interpret-mode int8 kernels, the dominant cost here, and
    all three benchmark phases reuse the same pair."""
    if name not in _ENGINES:
        m = SPACE_MODELS[name]
        calib = [m.synthetic_input(jax.random.PRNGKey(i))
                 for i in range(N_CALIB)]
        pair = []
        for fuse in (True, False):
            e = Engine(m.build_graph(),
                       m.init_params(jax.random.PRNGKey(0)), fuse=fuse)
            e.calibrate(calib)
            pair.append(e)
        _ENGINES[name] = (m, pair[0], pair[1])
    return _ENGINES[name]


def plan_table() -> List[Dict]:
    rows = []
    for name in SPACE_MODELS:
        m, ef, eu = _engines(name)
        fused = ef.planned("accel")
        unfused = eu.planned("accel")
        fs = fused.cost_signature(SERVE_RUNG)
        us = unfused.cost_signature(SERVE_RUNG)
        arena = fused.arena
        rows.append({
            "model": name, "rung": SERVE_RUNG,
            "fused_ddr_bytes": fs.bytes_moved,
            "unfused_ddr_bytes": us.bytes_moved,
            "ddr_reduction_x": us.bytes_moved / max(fs.bytes_moved, 1.0),
            "fused_mj_per_inf": fs.j_per_inference * 1e3,
            "unfused_mj_per_inf": us.j_per_inference * 1e3,
            "energy_reduction_x": (us.j_per_inference
                                   / max(fs.j_per_inference, 1e-30)),
            "n_fused_epilogues": len(fused.pass_report.fusion_groups),
            "n_requant_chains": len(fused.pass_report.requant_groups),
            "bram_peak": arena.bram_peak,
            "bram_budget": arena.bram_budget,
            "n_spilled": arena.n_spilled,
        })
    return rows


def check_table(rows: List[Dict]) -> Dict:
    print(f"\n{'model':18s} {'DDR x':>7s} {'J/inf x':>8s} "
          f"{'epi':>4s} {'rq':>3s} {'spill':>6s}")
    gates = {}
    for r in rows:
        print(f"{r['model']:18s} {r['ddr_reduction_x']:7.2f} "
              f"{r['energy_reduction_x']:8.3f} "
              f"{r['n_fused_epilogues']:4d} {r['n_requant_chains']:3d} "
              f"{r['n_spilled']:6d}")
        if r["model"] in GATED_MODELS:
            gates[r["model"]] = (
                r["fused_ddr_bytes"] < r["unfused_ddr_bytes"]
                and r["fused_mj_per_inf"] < r["unfused_mj_per_inf"])
    return gates


def conformance_check(n: int = 4) -> bool:
    ok = True
    for name in GATED_MODELS:
        m, ef, eu = _engines(name)
        inputs = m.synthetic_batch(jax.random.PRNGKey(99), n)
        rngs = jax.random.split(jax.random.PRNGKey(7), n)
        a = ef.run_batch(inputs, "accel", rngs)
        b = eu.run_batch(inputs, "accel", rngs)
        for k in a:
            same = np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
            ok = ok and same
            if not same:
                print(f"  CONFORMANCE FAIL {name}/accel/{k}")
    print(f"\n[conformance] fused == unfused (accel, bit-exact): {ok}")
    return ok


def _throughput(engine: Engine, m, batch: int) -> float:
    inputs = m.synthetic_batch(jax.random.PRNGKey(1), batch)
    rngs = jax.random.split(jax.random.PRNGKey(2), batch)
    engine.run_batch(inputs, "flex", rngs)      # compile + warm
    best = float("inf")
    for _ in range(WALL_REPEATS):
        t0 = time.perf_counter()
        out = engine.run_batch(inputs, "flex", rngs)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return batch / best


def wall_clock() -> Dict:
    res = {}
    for name in GATED_MODELS:
        m, ef, eu = _engines(name)
        fused_fps = _throughput(ef, m, WALL_BATCH)
        unfused_fps = _throughput(eu, m, WALL_BATCH)
        ratio = fused_fps / unfused_fps
        res[name] = {"fused_fps": fused_fps, "unfused_fps": unfused_fps,
                     "ratio": ratio, "ok": ratio >= WALL_TOLERANCE}
        print(f"[wall] {name:18s} flex b{WALL_BATCH}: fused "
              f"{fused_fps:9.2f} fps vs unfused {unfused_fps:9.2f} fps "
              f"(x{ratio:.3f})")
    return res


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="machine-independent gates only (skip wall-clock)")
    args = ap.parse_args(argv)

    print("== fused vs op-by-op plans (accel, serving rung "
          f"{SERVE_RUNG}) ==")
    rows = plan_table()
    table_gates = check_table(rows)
    conform_ok = conformance_check()
    wall = {} if args.smoke else wall_clock()

    gates = {f"{name}_fusion_reduces_ddr_and_j": ok
             for name, ok in table_gates.items()}
    gates["fused_bit_exact_accel"] = conform_ok
    if wall:
        gates["no_batch32_wallclock_regression"] = all(
            w["ok"] for w in wall.values())
    with open(OUT_PATH, "w") as f:
        json.dump({"plan_table": rows, "wall_clock": wall,
                   "gates": gates}, f, indent=1)
    print(f"\n[fusion] wrote {len(rows)} plan rows -> {OUT_PATH}")
    print("[gates] " + "  ".join(f"{k}={v}" for k, v in gates.items()))
    return 0 if all(gates.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
