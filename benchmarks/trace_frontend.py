"""Jaxpr front-end benchmark — trace fidelity + the never-hand-built
demo serve (DESIGN.md §14), gated -> BENCH_trace.json.

Three parts:

1. **Structure table** (machine-independent): for every space model, the
   traced graph's node count, param count, and MACs against the
   hand-built builder. Gate: op sequences, param totals, and MAC totals
   are identical for all six — the tracer reconstructs the hand-built
   graph, it doesn't approximate it.
2. **Bit-exactness** (machine-independent): traced engines match
   hand-built engines bit-for-bit on flex AND accel after identical PTQ
   calibration — same ops in the same order over the same params lower
   to the same XLA programs, so any drift is a translator bug.
3. **Demo serve**: the depthwise-separable cloud-mask CNN (which exists
   only as a JAX function) goes trace -> inspect -> PTQ -> autotune ->
   scheduler serve. Gates: every request completes, and the inspector
   reports a genuine partial offload (grouped convs on flex, the rest
   quantized onto accel).

    PYTHONPATH=src python -m benchmarks.trace_frontend            # full
    PYTHONPATH=src python -m benchmarks.trace_frontend --smoke    # CI
"""
from __future__ import annotations

import argparse
import functools
import json
from typing import Dict, List

import jax
import numpy as np

from repro.core.engine import Engine
from repro.frontend import trace
from repro.frontend import demo as demo_mod
from repro.models import SPACE_MODELS, synthetic_requests

OUT_PATH = "BENCH_trace.json"
BACKENDS = ("flex", "accel")
N_CALIB = 4
CONFORM_N = {"flex": 4, "accel": 2}   # accel is interpret-mode on hosts
DEMO_REQUESTS = {False: 32, True: 8}  # full / --smoke


_PAIRS = {}


def _pair(name: str):
    """(model, hand-built engine, traced engine) — memoized; the traced
    engine adopts the hand-built engine's PTQ calibration so identical
    quantization scales are a shared input, and bit-exactness isolates
    the traced graph itself."""
    if name not in _PAIRS:
        m = SPACE_MODELS[name]
        g = m.build_graph()
        params = m.init_params(jax.random.PRNGKey(0))
        tm = trace(functools.partial(m.jax_forward, params),
                   dict(g.graph_inputs), name=name + "_traced")
        e0 = Engine(g, params)
        e0.calibrate(synthetic_requests(m, N_CALIB, seed=0))
        e1 = Engine(tm.graph, tm.params)
        e1.calibrate(synthetic_requests(m, N_CALIB, seed=0))
        _PAIRS[name] = (m, g, tm, e0, e1)
    return _PAIRS[name]


def structure_table() -> List[Dict]:
    print(f"{'model':18s} {'nodes':>6s} {'params':>10s} {'MACs':>13s} "
          f"{'ops==':>6s}")
    rows = []
    for name in SPACE_MODELS:
        _, g, tm, _, _ = _pair(name)
        same_ops = ([g.nodes[n].op for n in g.order]
                    == [tm.graph.nodes[n].op for n in tm.graph.order])
        rows.append({
            "model": name,
            "traced_nodes": len(tm.graph.order),
            "hand_nodes": len(g.order),
            "traced_params": tm.graph.n_params,
            "hand_params": g.n_params,
            "traced_macs": tm.graph.n_macs,
            "hand_macs": g.n_macs,
            "ops_identical": same_ops,
        })
        print(f"{name:18s} {len(tm.graph.order):6d} "
              f"{tm.graph.n_params:10d} {tm.graph.n_macs:13d} "
              f"{str(same_ops):>6s}")
    return rows


def conformance_check() -> bool:
    ok = True
    for name in SPACE_MODELS:
        m, _, _, e0, e1 = _pair(name)
        for backend in BACKENDS:
            n = CONFORM_N[backend]
            inputs = m.synthetic_batch(jax.random.PRNGKey(123), n)
            rngs = jax.random.split(jax.random.PRNGKey(7), n)
            a = e0.run_batch(inputs, backend, rngs)
            b = e1.run_batch(inputs, backend, rngs)
            same = (set(a) == set(b) and all(
                np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
                for k in a))
            ok = ok and same
            if not same:
                print(f"  CONFORMANCE FAIL {name}/{backend}")
    print(f"\n[conformance] traced == hand-built "
          f"(flex+accel, bit-exact): {ok}")
    return ok


def demo_serve(smoke: bool) -> Dict:
    n = DEMO_REQUESTS[smoke]
    facts = demo_mod.run_demo(n_requests=n, batch_top=8,
                              autotune=not smoke, verbose=False)
    print(f"[demo] cloud_mask_cnn: {facts['n_completed']}/{n} served, "
          f"{facts['n_kept']} kept, {facts['mac_coverage']:.1%} MACs on "
          f"accel across {facts['n_segments']} segments")
    return facts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: fewer demo requests, no autotune")
    args = ap.parse_args(argv)

    print("== traced vs hand-built graphs (six space models) ==")
    rows = structure_table()
    gates = {
        "structure_identical": all(
            r["ops_identical"]
            and r["traced_params"] == r["hand_params"]
            and r["traced_macs"] == r["hand_macs"] for r in rows),
        "traced_bit_exact_flex_accel": conformance_check(),
    }
    facts = demo_serve(args.smoke)
    gates["demo_all_requests_served"] = (
        facts["n_completed"] == facts["n_requests"])
    gates["demo_partial_offload"] = (
        not facts["fully_supported"] and 0.0 < facts["mac_coverage"] < 1.0)

    with open(OUT_PATH, "w") as f:
        json.dump({"structure_table": rows, "demo": facts,
                   "gates": gates}, f, indent=1)
    print(f"\n[trace] wrote {len(rows)} structure rows -> {OUT_PATH}")
    print("[gates] " + "  ".join(f"{k}={v}" for k, v in gates.items()))
    return 0 if all(gates.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
