"""Orbit-aware radiation benchmark (DESIGN.md §16), gated ->
BENCH_radiation.json. Everything runs under ``clock="modeled"`` — every
number and every gate is machine-independent.

Four parts:

1. **SAA-pass storm**: one full orbit of the periodic upset-rate model
   (eclipse phase factors x a 40x South Atlantic Anomaly window),
   sampled into a deterministic mixed schedule — single-bit, multi-bit
   burst, and control-path upsets — and injected while a live trace
   serves through the SAA pass. Gates: every class is represented and
   fully detected within the self-test bound, every event recovers, the
   arena is bit-exact pristine after, zero drop/dup.
2. **Protection regime switch**: ``choose_protection`` priced on
   baseline_net's REAL packed arena (~0.9 MiB int8) and real autotuned
   rung-16 signature. Gates: the chosen mode flips between the quiet
   orbit (canary-only wins) and the SAA pass (ECC wins), with the full
   modeled-J/inf ordering asserted; a live ECC-armed serve then
   corrects a correctable burst at injection with zero weight damage.
3. **Checkpoint cadence**: ``optimize_cadence`` with the checkpoint
   cost priced from the bytes of a REAL scheduler+controller
   checkpoint. Gates: the chosen cadence beats both a 10x finer and a
   10x coarser cadence on expected replay-loss + overhead, and a
   watchdog reboot at a cadence-aligned instant replays to a
   dispatch-for-dispatch identical, zero-loss completion.
4. **Inert-radiation identity pin**: a controller armed with a
   sampled-EMPTY radiation schedule (zero-length horizon) leaves the
   scheduler dispatch-for-dispatch and bit-exact identical to serving
   with no controller at all — orbit awareness costs nothing when off.

    PYTHONPATH=src python -m benchmarks.radiation            # full
    PYTHONPATH=src python -m benchmarks.radiation --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.core import energy, faults, radiation
from repro.core.engine import Engine
from repro.core.scheduler import ContinuousBatchingScheduler, bursty_arrivals
from repro.models import SPACE_MODELS, synthetic_requests

OUT_PATH = "BENCH_radiation.json"
STORM_MODEL = "multi_esperta"        # six int8 dense heads -> real arenas
SWITCH_MODEL = "baseline_net"        # ~0.9 MiB packed arena, real CNN
CO_MODEL = "logistic_net"
BACKENDS = ("accel", "cpu")
LADDER = (1, 4, 16)
N_CALIB = 2
PERIOD = 0.05                        # self-test period (virtual s)
STORM_SEED = 4                       # sampled orbit schedule: 15 upsets,
                                     # all three classes, SAA-clustered
N_ORBIT_REQS = 64                    # trace covering the whole orbit
ORBIT_GAP_S = 0.03                   # burst spacing < PERIOD so the
                                     # modeled clock never idles past a
                                     # due self-test for long
QUIET_BASE_RATE = 0.5                # solar-max GCR floor (upsets/s);
                                     # puts the quiet orbit and the SAA
                                     # pass on opposite sides of the
                                     # measured none<->ecc crossover
DETECT_SLACK_S = 0.01
REBOOT_PERIOD = 0.01                 # fast self-tests for the replay
REBOOT_UPSETS = (                    # pre-cut pair recovered before the
    radiation.UpsetEvent(0.005),     # checkpoint; post-cut pair lands
    radiation.UpsetEvent(0.008, "mbu", span=3),  # in the resumed half
    radiation.UpsetEvent(0.038),
    radiation.UpsetEvent(0.045, "mbu", span=2),
)

_ENGINES = {}


def _engines(name: str) -> Tuple:
    if name not in _ENGINES:
        m = SPACE_MODELS[name]
        e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
        e.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                     for i in range(N_CALIB)])
        _ENGINES[name] = (m, e)
    return _ENGINES[name]


def _misses(sched) -> int:
    return sum(1 for c in sched.completions if c.missed_deadline)


def _zero_drop_dup(sched, n: int) -> bool:
    rids = sorted(c.rid for c in sched.completions)
    return rids == list(range(n))


def _arena_pristine(plan) -> bool:
    return all(np.array_equal(np.asarray(plan.weight_arena[n]),
                              plan.host_weights[n])
               for n in plan.weight_arena)


def _sched_for(name: str, n: int, burst: int, gap: float,
               ladder=LADDER) -> Tuple[ContinuousBatchingScheduler, List,
                                       List]:
    m, e = _engines(name)
    reqs = synthetic_requests(m, n, seed=5)
    times = bursty_arrivals(n, burst_size=burst, gap_s=gap, seed=20)
    sched = ContinuousBatchingScheduler(clock="modeled")
    sched.register(name, e, backend=BACKENDS, ladder=ladder,
                   warmup_sample=reqs[0])
    return sched, [(t, name, r) for t, r in zip(times, reqs)], reqs


# ---------------------------------------------------------------------------
# part 1: a full sampled orbit through the SAA pass
# ---------------------------------------------------------------------------


def saa_storm() -> Dict:
    env = radiation.RadiationEnvironment()
    upsets = env.sample_upsets(STORM_SEED, env.orbit_s)
    sched, trace, reqs = _sched_for(STORM_MODEL, N_ORBIT_REQS, 4,
                                    ORBIT_GAP_S)
    ctl = faults.FaultController(faults.FaultConfig(
        seed=0, upsets=upsets, self_test_period=PERIOD,
        recovery="repack"))
    sched.attach_faults(ctl)
    ctl.arm(sched, STORM_MODEL, reqs[:1])
    end = sched.serve_trace(trace)
    rep = ctl.report()

    n_saa = sum(1 for u in upsets if env.in_saa(u.t))
    # detection bound: next due test (<= one period away) + busy-deferral
    # aging + the idle gap between bursts, one dispatch, and the canary
    bound = (PERIOD * (1.0 + ctl.config.aging_fraction)
             + ORBIT_GAP_S + DETECT_SLACK_S)
    per = rep["per_class"]
    classes_ok = all(per[k]["n_injected"] > 0
                     for k in ("single", "mbu", "control"))
    detect_ok = (rep["n_injected"] == len(upsets)
                 and rep["n_detected"] == rep["n_injected"]
                 and all(e["detected_at"] is not None
                         and e["detected_at"] - e["t_injected"] <= bound
                         for e in rep["events"]))
    recovered_ok = rep["n_recovered"] == rep["n_injected"] and all(
        e["recovered_at"] is not None
        and e["recovered_at"] >= e["detected_at"] for e in rep["events"])
    plan = ctl._models[STORM_MODEL].plan
    res = {
        "n_upsets": len(upsets), "n_in_saa": n_saa,
        "expected_upsets_per_orbit": env.expected_upsets(0.0, env.orbit_s),
        "per_class": {k: per[k]["n_injected"]
                      for k in ("single", "mbu", "control")},
        "virtual_end_s": end, "detection_bound_s": bound,
        "deadline_misses": _misses(sched),
        "report": rep,
        "gates": {
            "storm_all_classes_injected": classes_ok,
            "storm_saa_events_present": n_saa > 0,
            "storm_all_detected_within_bound": detect_ok,
            "storm_all_recovered": recovered_ok,
            "storm_arena_bit_exact_after": _arena_pristine(plan),
            "storm_zero_drop_dup": _zero_drop_dup(sched, len(trace)),
            "storm_overhead_priced": rep["overhead_energy_j"] > 0,
        },
    }
    print(f"[saa-storm] sampled {len(upsets)} upsets over one "
          f"{env.orbit_s*1e3:.0f} ms orbit (expected "
          f"{res['expected_upsets_per_orbit']:.1f}): "
          f"{res['per_class']}  in-SAA={n_saa}  "
          f"detected={rep['n_detected']} recovered={rep['n_recovered']}  "
          f"max detection latency="
          f"{rep['max_detection_latency_s']*1e3:.1f} ms "
          f"(bound {bound*1e3:.0f} ms)")
    return res


# ---------------------------------------------------------------------------
# part 2: protection mode flips between quiet orbit and SAA pass
# ---------------------------------------------------------------------------


def protection_switch() -> Dict:
    sched, trace, reqs = _sched_for(SWITCH_MODEL, 8, 4, 0.02)
    base_sig = {r: sched._svcs[SWITCH_MODEL].costs[("accel", r)]
                for r in LADDER}
    env = radiation.RadiationEnvironment(base_rate=QUIET_BASE_RATE)
    # a live ECC-armed serve: correctable bursts fixed at injection
    ctl = faults.FaultController(faults.FaultConfig(
        seed=0, self_test_period=PERIOD, protection="ecc",
        upsets=(radiation.UpsetEvent(0.005, "mbu", span=3),
                radiation.UpsetEvent(0.012))))
    sched.attach_faults(ctl)
    ctl.arm(sched, SWITCH_MODEL, reqs[:1])
    am = ctl._models[SWITCH_MODEL]
    packed = sum(int(np.asarray(a).nbytes)
                 for a in am.plan.weight_arena.values())
    sig = sched._svcs[SWITCH_MODEL].costs[("accel", LADDER[-1])]
    p_unc = env.uncorrectable_fraction(am.domains.n_domains)
    quiet_rate, saa_rate = env.rate(0.05), env.rate(0.25)
    quiet_best, quiet = faults.choose_protection(
        "accel", base_sig[LADDER[-1]], packed, am.canary.cost,
        upset_rate=quiet_rate, p_uncorrectable=p_unc)
    saa_best, saa = faults.choose_protection(
        "accel", base_sig[LADDER[-1]], packed, am.canary.cost,
        upset_rate=saa_rate, p_uncorrectable=p_unc)

    sched.serve_trace(trace)
    rep = ctl.report()
    ecc_live_ok = (rep["n_injected"] == 2
                   and rep["n_recovered"] == 2
                   and rep["n_corrected"] == 2
                   and ctl.injector.n_flips == 0  # no bit ever landed
                   and all(e["action"] == "ecc-correct"
                           and e["detected_at"] == e["t_injected"]
                           for e in rep["events"]))
    priced_ok = (sig.protection == "ecc"
                 and all(sched._svcs[SWITCH_MODEL]
                         .costs[("accel", r)].j_per_inference
                         > base_sig[r].j_per_inference for r in LADDER))
    res = {
        "packed_bytes": packed, "p_uncorrectable": p_unc,
        "quiet_rate_hz": quiet_rate, "saa_rate_hz": saa_rate,
        "quiet": {"best": quiet_best, "table": quiet},
        "saa": {"best": saa_best, "table": saa},
        "gates": {
            "switch_quiet_prefers_canary_only": quiet_best == "none",
            "switch_saa_prefers_ecc": saa_best == "ecc",
            "switch_mode_changes_with_regime": quiet_best != saa_best,
            "switch_quiet_ordering": (quiet["none"] < quiet["ecc"]
                                      < quiet["tmr"]),
            "switch_saa_ordering": (saa["ecc"] < saa["none"]
                                    and saa["ecc"] < saa["tmr"]),
            "switch_ecc_serve_corrects_at_injection": ecc_live_ok,
            "switch_ecc_costs_priced_in": priced_ok,
            "switch_arena_bit_exact_after": _arena_pristine(am.plan),
            "switch_zero_drop_dup": _zero_drop_dup(sched, len(trace)),
        },
    }
    print(f"[switch] {SWITCH_MODEL} arena {packed/1024:.0f} KiB  "
          f"quiet {quiet_rate:.2f}/s -> {quiet_best} "
          f"(J/inf none={quiet['none']:.3e} ecc={quiet['ecc']:.3e} "
          f"tmr={quiet['tmr']:.3e})  SAA {saa_rate:.1f}/s -> {saa_best} "
          f"(none={saa['none']:.3e} ecc={saa['ecc']:.3e} "
          f"tmr={saa['tmr']:.3e})")
    return res


# ---------------------------------------------------------------------------
# part 3: checkpoint cadence + a cadence-aligned watchdog reboot
# ---------------------------------------------------------------------------


def _reboot_sched() -> Tuple[ContinuousBatchingScheduler, List, List]:
    return _sched_for(STORM_MODEL, 24, 4, 0.01, ladder=(1, 4))


def _reboot_ctl(sched, reqs) -> faults.FaultController:
    ctl = faults.FaultController(faults.FaultConfig(
        seed=0, upsets=REBOOT_UPSETS, self_test_period=REBOOT_PERIOD))
    sched.attach_faults(ctl)
    ctl.arm(sched, STORM_MODEL, reqs[:1])
    return ctl


def cadence_check() -> Dict:
    env = radiation.RadiationEnvironment()
    # price the checkpoint from the bytes of a REAL ledger: serve the
    # storm once, snapshot scheduler + controller, measure the file
    full, trace, reqs = _reboot_sched()
    ctl_full = _reboot_ctl(full, reqs)
    full.serve_trace(trace)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ck.npz")
        faults.save_checkpoint(path, {"sched": full.state_dict(),
                                      "faults": ctl_full.state_dict()})
        ckpt_bytes = os.path.getsize(path)
    ckpt_cost = energy.repack_cost(energy.BACKEND_HW["cpu"],
                                   ckpt_bytes).seconds
    plan = radiation.optimize_cadence(env, horizon_s=env.orbit_s,
                                      checkpoint_cost_s=ckpt_cost)
    finer = radiation.expected_replay_cost(env, env.orbit_s,
                                           plan.cadence_s / 10.0,
                                           ckpt_cost)
    coarser = radiation.expected_replay_cost(env, env.orbit_s,
                                             plan.cadence_s * 10.0,
                                             ckpt_cost)

    # the watchdog reboot, cut at a checkpoint instant on the chosen
    # cadence (k*T aligned near mid-trace, after the first upset pair
    # has recovered and before the second lands)
    k = max(1, round(0.03 / plan.cadence_s))
    cut = k * plan.cadence_s
    first, _, reqs1 = _reboot_sched()
    ctl1 = _reboot_ctl(first, reqs1)
    now = first.serve_trace(trace, stop_at=cut)
    pre_recovered = all(e["recovered_at"] is not None
                        for e in ctl1.report()["events"])
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ck.npz")
        faults.save_checkpoint(path, {"sched": first.state_dict(),
                                      "faults": ctl1.state_dict()})
        state = faults.load_checkpoint(path)
    second, _, reqs2 = _reboot_sched()
    ctl2 = _reboot_ctl(second, reqs2)
    second.load_state_dict(state["sched"])
    ctl2.load_state_dict(state["faults"])
    rest = [e for e in trace if e[0] > now + 1e-12]
    second.serve_trace(rest, start=now)

    rep2 = ctl2.report()
    n = len(trace)
    meta = lambda s: [(c.rid, c.model, c.kept, c.arrival, c.finished,
                       c.rung, c.n_real, c.deadline) for c in s.completions]
    identical = meta(second) == meta(full)
    same_dispatches = second.dispatches == full.dispatches
    res = {
        "checkpoint_bytes": ckpt_bytes, "checkpoint_cost_s": ckpt_cost,
        "cadence_s": plan.cadence_s,
        "expected_cost_s": plan.expected_cost_s,
        "n_checkpoints_per_orbit": plan.n_checkpoints,
        "cost_10x_finer_s": finer, "cost_10x_coarser_s": coarser,
        "reboot_cut_s": cut, "reboot_cut_multiple": k,
        "gates": {
            "cadence_beats_10x_finer": plan.expected_cost_s < finer,
            "cadence_beats_10x_coarser": plan.expected_cost_s < coarser,
            "reboot_precut_storm_recovered": pre_recovered,
            "reboot_all_upsets_recovered": (
                rep2["n_injected"] == len(REBOOT_UPSETS)
                and rep2["n_recovered"] == rep2["n_injected"]),
            "reboot_zero_drop_dup": _zero_drop_dup(second, n),
            "reboot_completions_identical": identical,
            "reboot_dispatches_identical": same_dispatches,
        },
    }
    print(f"[cadence] checkpoint {ckpt_bytes/1024:.1f} KiB -> "
          f"{ckpt_cost*1e6:.2f} us; T*={plan.cadence_s*1e3:.2f} ms "
          f"({plan.n_checkpoints}/orbit) cost={plan.expected_cost_s*1e3:.2f}"
          f" ms vs /10={finer*1e3:.2f} ms, x10={coarser*1e3:.2f} ms; "
          f"reboot at {cut*1e3:.1f} ms (k={k}) identical="
          f"{identical and same_dispatches}")
    return res


# ---------------------------------------------------------------------------
# part 4: inert-radiation identity pin
# ---------------------------------------------------------------------------


def _co_sched() -> Tuple[ContinuousBatchingScheduler, List]:
    sched = ContinuousBatchingScheduler(clock="modeled")
    trace = []
    for mi, name in enumerate((STORM_MODEL, CO_MODEL)):
        m, e = _engines(name)
        reqs = synthetic_requests(m, 48, seed=5 + mi)
        sched.register(name, e, backend=BACKENDS, ladder=LADDER,
                       warmup_sample=reqs[0])
        trace += [(t, name, r) for t, r in
                  zip(bursty_arrivals(48, burst_size=8, gap_s=0.02,
                                      seed=20 + mi), reqs)]
    return sched, trace


def identity_pin() -> Dict:
    plain, trace = _co_sched()
    plain.serve_trace(trace)

    armed, _ = _co_sched()
    # the inert-radiation config: a genuinely sampled (empty) schedule
    empty = radiation.RadiationEnvironment().sample_upsets(0, 0.0)
    ctl = faults.FaultController(faults.FaultConfig(upsets=empty))
    armed.attach_faults(ctl)
    for mi, name in enumerate((STORM_MODEL, CO_MODEL)):
        m, _ = _engines(name)
        ctl.arm(armed, name, synthetic_requests(m, 1, seed=5 + mi))
    armed.serve_trace(trace)

    same_dispatches = armed.dispatches == plain.dispatches
    tuples = lambda s: [(c.rid, c.model, c.kept, c.arrival, c.finished,
                         c.rung, c.n_real) for c in s.completions]
    same_completions = tuples(armed) == tuples(plain)
    bit_exact = same_completions and all(
        np.array_equal(a.outputs[k], b.outputs[k])
        for a, b in zip(armed.completions, plain.completions)
        for k in b.outputs)
    untouched = ctl.report()["n_injected"] == 0 \
        and ctl.report()["n_self_tests"] == 0
    print(f"[identity] inert radiation config: dispatches identical="
          f"{same_dispatches}  completions identical={same_completions}  "
          f"outputs bit-exact={bit_exact}")
    return {"gates": {
        "inert_radiation_dispatches_identical": same_dispatches,
        "inert_radiation_completions_identical": same_completions,
        "inert_radiation_outputs_bit_exact": bit_exact,
        "inert_radiation_controller_untouched": untouched,
    }}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="accepted for CI symmetry; every part is "
                         "modeled-clock and machine-independent, so "
                         "smoke runs the full gate set")
    ap.parse_args(argv)

    env = radiation.RadiationEnvironment()
    print(f"== orbit-aware radiation: one {env.orbit_s*1e3:.0f} ms orbit, "
          f"SAA x{env.saa_factor:.0f} over "
          f"[{env.saa_window[0]*1e3:.0f}, {env.saa_window[1]*1e3:.0f}] ms, "
          f"storm on {STORM_MODEL}, protection trade on {SWITCH_MODEL} ==")
    storm = saa_storm()
    switch = protection_switch()
    cadence = cadence_check()
    ident = identity_pin()
    gates = {}
    for part in (storm, switch, cadence, ident):
        gates.update(part["gates"])

    with open(OUT_PATH, "w") as f:
        json.dump({"storm": storm, "protection_switch": switch,
                   "cadence": cadence, "identity": ident, "gates": gates},
                  f, indent=1)
    print(f"\n[radiation] wrote {OUT_PATH}")
    print("[gates] " + "  ".join(f"{k}={v}" for k, v in gates.items()))
    return 0 if all(gates.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
