"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the ledger.

    PYTHONPATH=src python -m benchmarks.report            # prints markdown
"""
from __future__ import annotations

import json

from benchmarks.roofline import LEDGER, analyze_cell


def _fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(ledger, tag="baseline"):
    from repro.configs import all_archs, get_arch, shapes_for
    print(f"\n### Dry-run ledger — tag `{tag}`\n")
    print("| arch | shape | mesh | status | lower s | compile s | "
          "arg GiB/dev | temp GiB/dev | coll GB/dev (body x1) |")
    print("|---|---|---|---|---|---|---|---|---|")
    n_ok = n = 0
    for arch in all_archs():
        for shape in shapes_for(get_arch(arch)):
            for mesh in ("single", "multi"):
                rec = ledger.get(f"{tag}/{arch}/{shape.name}/{mesh}")
                if rec is None:
                    continue
                n += 1
                ok = rec.get("status") == "ok"
                n_ok += ok
                if not ok:
                    print(f"| {arch} | {shape.name} | {mesh} | FAIL | | | | | |")
                    continue
                m = rec.get("memory", {})
                print(f"| {arch} | {shape.name} | {mesh} | ok "
                      f"| {rec.get('lower_s','')} | {rec.get('compile_s','')} "
                      f"| {_fmt_bytes(m.get('argument_size_in_bytes',0))} "
                      f"| {_fmt_bytes(m.get('temp_size_in_bytes',0))} "
                      f"| {rec.get('collectives',{}).get('total',0)/1e9:.2f} |")
    print(f"\n{n_ok}/{n} cells ok.\n")


def roofline_table(ledger, tag="baseline", title=""):
    from repro.configs import all_archs, get_arch, shapes_for
    print(f"\n### Roofline — tag `{tag}` {title}\n")
    print("(per-chip seconds; single-pod 256-chip mesh; scan-corrected)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| MODEL_FLOPS | useful | roofline |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in all_archs():
        for shape in shapes_for(get_arch(arch)):
            r = analyze_cell(ledger, tag, arch, shape.name)
            if r is None:
                continue
            print(f"| {arch} | {shape.name} | {r['t_compute_s']:.4g} "
                  f"| {r['t_memory_s']:.4g} | {r['t_collective_s']:.4g} "
                  f"| {r['dominant']} | {r['model_flops']:.3g} "
                  f"| {r['useful_ratio']:.2f} "
                  f"| {r['roofline_frac']*100:.1f}% |")
    print()


def main() -> None:
    with open(LEDGER) as f:
        ledger = json.load(f)
    dryrun_table(ledger, "baseline")
    roofline_table(ledger, "baseline", "(paper-faithful baseline)")
    if any(k.startswith("opt/") for k in ledger):
        dryrun_table(ledger, "opt")
        roofline_table(ledger, "opt",
                       "(beyond-paper: a2a EP + explicit SP + serving "
                       "sharding + w8 + int8-KV)")


if __name__ == "__main__":
    main()
