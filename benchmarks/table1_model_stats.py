"""Paper Table I — parameters and operations per space model.

Builds every op graph, counts params / ops from shape inference, and
compares against the paper's published numbers (tolerance: the paper does
not publish exact channel widths for the VAE/CNet, which we calibrated to
match within <2%).
"""
from __future__ import annotations

from repro.models import SPACE_MODELS

COLS = f"{'model':18s} {'params':>10s} {'paper':>10s} {'Δ%':>6s} " \
       f"{'ops':>13s} {'paper':>13s} {'Δ%':>6s}"


def rows():
    out = []
    for name, m in SPACE_MODELS.items():
        g = m.build_graph()
        dp = 100.0 * (g.n_params - m.paper_params) / m.paper_params
        do = 100.0 * (g.n_ops - m.paper_ops) / m.paper_ops
        out.append({
            "model": name,
            "params": g.n_params, "paper_params": m.paper_params,
            "params_err_pct": dp,
            "ops": g.n_ops, "paper_ops": m.paper_ops,
            "ops_err_pct": do,
        })
    return out


def main() -> None:
    print("== Table I: parameters and operations ==")
    print(COLS)
    for r in rows():
        print(f"{r['model']:18s} {r['params']:10d} {r['paper_params']:10d} "
              f"{r['params_err_pct']:+5.1f} {r['ops']:13d} "
              f"{r['paper_ops']:13d} {r['ops_err_pct']:+5.1f}")


if __name__ == "__main__":
    main()
