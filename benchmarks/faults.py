"""Degraded-mode fault-injection benchmark (DESIGN.md §13), gated ->
BENCH_faults.json. Everything runs under ``clock="modeled"`` — every
number and every gate is machine-independent.

Four parts:

1. **Storm A — repack recovery**: a deterministic 3-SEU storm against
   multi_esperta's accel weight arenas while a bursty trace serves.
   Gates: every injected fault is detected by an in-band canary within
   the self-test period (plus the low-priority aging allowance), every
   recovery re-packs the arena back to bit-exact pristine weights, no
   accepted request is dropped or duplicated, and the storm adds only a
   bounded number of deadline misses over a fault-free run of the SAME
   trace.
2. **Storm B — demote recovery**: same storm, but detection quarantines
   the accel backend so dispatch falls back through the multi-backend
   registration (cpu) until a delayed repair. Gates: fallback dispatches
   actually happen during quarantine, the quarantine is lifted after
   repair, recovery is bit-exact, zero drop/dup.
3. **Watchdog reboot**: serve a two-model trace to ``stop_at``, write
   the scheduler ledger through ``save_checkpoint``/``load_checkpoint``
   (one .npz, no pickle), restore into a FRESH scheduler with freshly
   registered models, and serve the remainder. Gates: the combined run
   completes every accepted request exactly once, and is dispatch-for-
   dispatch + completion-metadata IDENTICAL to the uninterrupted run
   (post-reboot outputs bit-exact).
4. **Inert-controller identity pin**: with ``fault_rate=0`` and no
   self-test period, an attached+armed controller leaves the scheduler
   dispatch-for-dispatch and bit-exact identical to serving with no
   controller at all — degraded-mode support costs nothing when off.

    PYTHONPATH=src python -m benchmarks.faults            # full
    PYTHONPATH=src python -m benchmarks.faults --smoke    # CI (same gates)
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.core import faults
from repro.core.engine import Engine
from repro.core.scheduler import ContinuousBatchingScheduler, bursty_arrivals
from repro.models import SPACE_MODELS, synthetic_requests

OUT_PATH = "BENCH_faults.json"
STORM_MODEL = "multi_esperta"        # six int8 dense heads -> real arenas
CO_MODEL = "logistic_net"
BACKENDS = ("accel", "cpu")
LADDER = (1, 4, 16)
N_REQUESTS = 48
N_CALIB = 2
PERIOD = 0.05                        # self-test period (virtual s)
FAULT_TIMES = (0.011, 0.043, 0.087) # the deterministic 3-SEU storm —
                                     # all inside the ~0.1 s burst span
REPAIR_DELAY = 0.04                  # demote-mode watchdog repair delay
STOP_AT = 0.05                       # reboot point (mid-trace)
MAX_EXTRA_MISSES = 8                 # storm deadline-miss allowance
# detection bound: next due test (<= one period away) + busy-deferral
# aging (0.5 period) + one in-flight dispatch and the canary itself
DETECT_SLACK_S = 0.01

_ENGINES = {}


def _engines(name: str) -> Tuple:
    if name not in _ENGINES:
        m = SPACE_MODELS[name]
        e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
        e.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                     for i in range(N_CALIB)])
        _ENGINES[name] = (m, e)
    return _ENGINES[name]


def _storm_trace() -> Tuple[List, List[Dict]]:
    m, _ = _engines(STORM_MODEL)
    reqs = synthetic_requests(m, N_REQUESTS, seed=5)
    times = bursty_arrivals(N_REQUESTS, burst_size=8, gap_s=0.02, seed=20)
    return [(t, STORM_MODEL, r) for t, r in zip(times, reqs)], reqs


def _storm_sched() -> ContinuousBatchingScheduler:
    _, e = _engines(STORM_MODEL)
    sched = ContinuousBatchingScheduler(clock="modeled")
    sched.register(STORM_MODEL, e, backend=BACKENDS, ladder=LADDER,
                   warmup_sample=synthetic_requests(
                       _engines(STORM_MODEL)[0], 1, seed=5)[0])
    return sched


def _misses(sched) -> int:
    return sum(1 for c in sched.completions if c.missed_deadline)


def _zero_drop_dup(sched, n: int) -> bool:
    rids = sorted(c.rid for c in sched.completions)
    return rids == list(range(n))


# ---------------------------------------------------------------------------
# parts 1 + 2: fault storms
# ---------------------------------------------------------------------------


def run_storm(recovery: str) -> Dict:
    trace, reqs = _storm_trace()
    sched = _storm_sched()
    ctl = faults.FaultController(faults.FaultConfig(
        seed=0, fault_times=FAULT_TIMES, self_test_period=PERIOD,
        recovery=recovery, repair_delay_s=REPAIR_DELAY))
    sched.attach_faults(ctl)
    ctl.arm(sched, STORM_MODEL, reqs[:1])
    end = sched.serve_trace(trace)
    rep = ctl.report()

    bound = PERIOD * (1.0 + ctl.config.aging_fraction) + DETECT_SLACK_S
    detect_ok = (rep["n_injected"] == len(FAULT_TIMES)
                 and rep["n_detected"] == rep["n_injected"]
                 and all(e["detected_at"] is not None
                         and e["detected_at"] - e["t_injected"] <= bound
                         for e in rep["events"]))
    recovered_ok = rep["n_recovered"] == rep["n_injected"] and all(
        e["recovered_at"] is not None
        and e["recovered_at"] >= e["detected_at"] for e in rep["events"])
    # the arena itself must be back to pristine bits, not just digests
    plan = ctl._models[STORM_MODEL].plan
    arena_ok = all(np.array_equal(np.asarray(plan.weight_arena[n]),
                                  plan.host_weights[n])
                   for n in plan.weight_arena)
    res = {
        "recovery": recovery, "virtual_end_s": end, "report": rep,
        "detection_bound_s": bound,
        "deadline_misses": _misses(sched),
        "gates": {
            f"{recovery}_all_detected_within_bound": detect_ok,
            f"{recovery}_all_recovered": recovered_ok,
            f"{recovery}_arena_bit_exact_after": arena_ok,
            f"{recovery}_zero_drop_dup": _zero_drop_dup(sched, len(trace)),
            f"{recovery}_overhead_priced": rep["overhead_energy_j"] > 0,
        },
    }
    if recovery == "demote":
        fb = sum(1 for d in sched.dispatches
                 if d.model == STORM_MODEL and d.backend != BACKENDS[0])
        res["n_fallback_dispatches"] = fb
        res["gates"]["demote_fallback_dispatches"] = fb > 0
        res["gates"]["demote_unquarantined_at_end"] = (
            not sched._svcs[STORM_MODEL].quarantined)
    print(f"[storm/{recovery}] injected={rep['n_injected']} "
          f"detected={rep['n_detected']} recovered={rep['n_recovered']} "
          f"max detection latency="
          f"{rep['max_detection_latency_s']*1e3:.2f} ms "
          f"(bound {bound*1e3:.0f} ms)  self-tests={rep['n_self_tests']}  "
          f"overhead={rep['overhead_energy_j']*1e3:.3f} mJ  "
          f"misses={res['deadline_misses']}")
    return res


def clean_baseline() -> Dict:
    trace, _ = _storm_trace()
    sched = _storm_sched()
    sched.serve_trace(trace)
    return {"deadline_misses": _misses(sched),
            "n_completions": len(sched.completions)}


# ---------------------------------------------------------------------------
# part 3: watchdog reboot through a checkpoint file
# ---------------------------------------------------------------------------


def _co_sched() -> Tuple[ContinuousBatchingScheduler, List]:
    sched = ContinuousBatchingScheduler(clock="modeled")
    trace = []
    for mi, name in enumerate((STORM_MODEL, CO_MODEL)):
        m, e = _engines(name)
        reqs = synthetic_requests(m, N_REQUESTS, seed=5 + mi)
        sched.register(name, e, backend=BACKENDS, ladder=LADDER,
                       warmup_sample=reqs[0])
        trace += [(t, name, r) for t, r in
                  zip(bursty_arrivals(N_REQUESTS, burst_size=8, gap_s=0.02,
                                      seed=20 + mi), reqs)]
    return sched, trace


def reboot_check() -> Dict:
    full, trace = _co_sched()
    full.serve_trace(trace)

    first, _ = _co_sched()
    now = first.serve_trace(trace, stop_at=STOP_AT)
    n_before = len(first.completions)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "sched.npz")
        faults.save_checkpoint(path, first.state_dict())
        size = os.path.getsize(path)
        state = faults.load_checkpoint(path)
    # the reboot: a fresh process re-registers the same models (pristine
    # bitstream + weights), then the ledger restore resumes the queues
    second, _ = _co_sched()
    second.load_state_dict(state)
    rest = [e for e in trace if e[0] > now + 1e-12]
    second.serve_trace(rest, start=now)

    n = len(trace)
    zero_loss = _zero_drop_dup(second, n)
    meta = [(c.rid, c.model, c.kept, c.arrival, c.finished, c.rung,
             c.n_real, c.deadline) for c in second.completions]
    meta_full = [(c.rid, c.model, c.kept, c.arrival, c.finished, c.rung,
                  c.n_real, c.deadline) for c in full.completions]
    identical = meta == meta_full
    same_dispatches = second.dispatches == full.dispatches
    by_rid = {c.rid: c for c in full.completions}
    bit_exact = all(
        np.array_equal(c.outputs[k], by_rid[c.rid].outputs[k])
        for c in second.completions if c.outputs for k in c.outputs)
    print(f"[reboot] stop at t={now*1e3:.1f} ms with {n_before} done; "
          f"checkpoint {size/1024:.1f} KiB; resumed "
          f"{len(second.completions) - n_before} more -> "
          f"{len(second.completions)}/{n} total  zero-loss={zero_loss}  "
          f"identical-to-uninterrupted={identical and same_dispatches}")
    return {
        "stop_at_s": now, "completed_before": n_before,
        "checkpoint_bytes": size, "n_requests": n,
        "gates": {
            "reboot_zero_drop_dup": zero_loss,
            "reboot_completions_identical": identical,
            "reboot_dispatches_identical": same_dispatches,
            "reboot_outputs_bit_exact": bit_exact,
        },
    }


# ---------------------------------------------------------------------------
# part 4: inert controller == no controller
# ---------------------------------------------------------------------------


def identity_pin() -> Dict:
    plain, trace = _co_sched()
    plain.serve_trace(trace)

    armed, _ = _co_sched()
    ctl = faults.FaultController(faults.FaultConfig())   # rate 0, no tests
    armed.attach_faults(ctl)
    for mi, name in enumerate((STORM_MODEL, CO_MODEL)):
        m, _ = _engines(name)
        ctl.arm(armed, name, synthetic_requests(m, 1, seed=5 + mi))
    armed.serve_trace(trace)

    same_dispatches = armed.dispatches == plain.dispatches
    tuples = lambda s: [(c.rid, c.model, c.kept, c.arrival, c.finished,
                         c.rung, c.n_real) for c in s.completions]
    same_completions = tuples(armed) == tuples(plain)
    bit_exact = same_completions and all(
        np.array_equal(a.outputs[k], b.outputs[k])
        for a, b in zip(armed.completions, plain.completions)
        for k in b.outputs)
    untouched = ctl.report()["n_injected"] == 0 \
        and ctl.report()["n_self_tests"] == 0
    print(f"[identity] inert controller: dispatches identical="
          f"{same_dispatches}  completions identical={same_completions}  "
          f"outputs bit-exact={bit_exact}")
    return {"gates": {
        "inert_dispatches_identical": same_dispatches,
        "inert_completions_identical": same_completions,
        "inert_outputs_bit_exact": bit_exact,
        "inert_controller_untouched": untouched,
    }}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="accepted for CI symmetry; every part is "
                         "modeled-clock and machine-independent, so "
                         "smoke runs the full gate set")
    ap.parse_args(argv)

    print(f"== degraded-mode fault injection: {len(FAULT_TIMES)}-SEU "
          f"storms on {STORM_MODEL} ({'+'.join(BACKENDS)}), self-test "
          f"period {PERIOD*1e3:.0f} ms, reboot at {STOP_AT*1e3:.0f} ms ==")
    clean = clean_baseline()
    storms = [run_storm("repack"), run_storm("demote")]
    gates = {}
    for s in storms:
        extra = s["deadline_misses"] - clean["deadline_misses"]
        gates[f"{s['recovery']}_bounded_extra_misses"] = (
            extra <= MAX_EXTRA_MISSES)
        s["extra_misses_vs_clean"] = extra
        gates.update(s["gates"])
    reboot = reboot_check()
    gates.update(reboot["gates"])
    ident = identity_pin()
    gates.update(ident["gates"])

    with open(OUT_PATH, "w") as f:
        json.dump({"clean_baseline": clean, "storms": storms,
                   "reboot": reboot, "identity": ident, "gates": gates},
                  f, indent=1)
    print(f"\n[faults] wrote {OUT_PATH}")
    print("[gates] " + "  ".join(f"{k}={v}" for k, v in gates.items()))
    return 0 if all(gates.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
