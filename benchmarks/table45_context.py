"""Paper Tables IV/V — related-work FPS/power context.

The paper situates its Vitis-AI and HLS results against other onboard
implementations. We print their published rows next to our reproduced
models (modeled ZCU104 FPS from table3) plus the modeled TPU-v5e numbers,
and the ops-per-second metric the paper notes is rarely reported.
"""
from __future__ import annotations

from repro.core.energy import (TPU_V5E, ZCU104_DPU, ZCU104_HLS_NAIVE,
                               model_graph)
from repro.models import SPACE_MODELS

# Published rows (paper Tables IV and V)
TABLE4 = [
    ("LD-UNet [13]", "ZCU104", 5_652, 632, 14.1),
    ("CAE [11]", "ZCU104", 2_950_000, 250, 5.3),
    ("ResNet-50 [28]", "ZCU102", None, 68, 30.0),
    ("mod. YOLOv4 [27]", "KV260", None, 3.8, None),
    ("YOLOv4-Mobv3 [26]", "KV260", 5_690_000, 48, 7.2),
    ("Pixel-Net [25]", "Ultra96-V2", 17_430, 0.051, 2.4),
    ("Patch-Net [25]", "Ultra96-V2", 13_000, 0.049, 2.5),
    ("Scene-Net [25]", "Ultra96-V2", 3_320_000, 57, 2.5),
    ("U-Net [25]", "Ultra96-V2", 26_620, 37, 2.4),
]
TABLE5 = [
    ("CNN [12]", "ZCU104", 245_000, 3_676, 9.493),
    ("TCN+U-Net [29]", "Z-7020", 2_000, 0.98, 0.196),
]


def main() -> None:
    print("== Tables IV/V context: our models vs published onboard work ==")
    print(f"{'network':22s} {'board':11s} {'#param':>10s} {'FPS':>10s} "
          f"{'power W':>8s} {'MOP/s':>10s}")
    for name, m in SPACE_MODELS.items():
        g = m.build_graph()
        if m.paper_toolchain == "vitis_ai":
            hw, backend = ZCU104_DPU, "accel"
        else:
            hw, backend = ZCU104_HLS_NAIVE, "flex"
        rep = model_graph(g, hw, backend)
        print(f"{name:22s} {'ZCU104*':11s} {g.n_params:10,d} {rep.fps:10.1f} "
              f"{hw.power_busy:8.2f} {rep.mops:10.1f}")
        tpu = model_graph(g, TPU_V5E, "accel")
        print(f"{'':22s} {'tpu_v5e*':11s} {'':>10s} {tpu.fps:10.1f} "
              f"{TPU_V5E.power_busy:8.0f} {tpu.mops:10.1f}")
    for name, board, params, fps, power in TABLE4 + TABLE5:
        p = f"{params:,d}" if params else "-"
        w = f"{power:.2f}" if power else "-"
        print(f"{name:22s} {board:11s} {p:>10s} {fps:10.2f} {w:>8s} "
              f"{'-':>10s}")
    print("\n* modeled (this work); published rows are measured. The paper's "
          "point stands: FPS alone is incomparable across parameter counts — "
          "MOP/s (reported for our rows) is the portable metric.")


if __name__ == "__main__":
    main()
