"""Paper Table II — accelerator resource footprint.

The ZCU104 columns (LUT/FF/DSP/BRAM) do not exist on TPU; the transferable
quantity is **on-chip weight residency**: the paper stores all HLS weights
in BRAM when they fit (<=4.75 MB) and spills BaselineNet to DRAM, while the
DPU holds ~3.92 MB of parameters in BRAM+URAM. Our analog is VMEM
residency of the INT8 (accel) / fp32 (flex) weights against the TPU v5e
VMEM budget, plus the inspector's op-coverage verdict — the two quantities
that decide which path a model takes and whether it pays HBM traffic
per inference.
"""
from __future__ import annotations

from repro.core.energy import TPU_V5E, ZCU104_DPU, weight_bytes
from repro.core.inspector import inspect
from repro.models import SPACE_MODELS


def rows():
    out = []
    for name, m in SPACE_MODELS.items():
        g = m.build_graph()
        rep = inspect(g)
        # actual post-PTQ widths: int8 weights + fp32 biases on the
        # quantizable (conv2d/dense) nodes, fp32 for flex-only ops — the
        # per-node dtype accounting BRAM residency uses (no more flat
        # 1 B or 4 B per param)
        int8_bytes = weight_bytes(g, "accel")
        fp32_bytes = weight_bytes(g, "flex")
        out.append({
            "model": name,
            "paper_toolchain": m.paper_toolchain,
            "int8_bytes": int8_bytes,
            "fp32_bytes": fp32_bytes,
            "vmem_resident_int8": int8_bytes <= TPU_V5E.onchip_bytes,
            "vmem_resident_fp32": fp32_bytes <= TPU_V5E.onchip_bytes,
            "bram_resident_fp32": fp32_bytes <= ZCU104_DPU.onchip_bytes,
            "accel_coverage": rep.mac_coverage,
            "fully_supported": rep.fully_supported,
            "unsupported": sorted(set(rep.unsupported)),
        })
    return out


def main() -> None:
    print("== Table II analog: weight footprint & residency ==")
    print(f"{'model':18s} {'int8':>9s} {'fp32':>10s} "
          f"{'VMEM(int8)':>10s} {'BRAM(fp32)':>10s} {'accel%':>7s}  notes")
    for r in rows():
        note = "full accel" if r["fully_supported"] else \
            f"flex ops: {','.join(r['unsupported'])}"
        print(f"{r['model']:18s} {r['int8_bytes']:9d} {r['fp32_bytes']:10d} "
              f"{'yes' if r['vmem_resident_int8'] else 'SPILL':>10s} "
              f"{'yes' if r['bram_resident_fp32'] else 'SPILL':>10s} "
              f"{r['accel_coverage']*100:6.1f}%  {note}")
    print("\npaper cross-check: BaselineNet fp32 (3.7 MB) close to the "
          "4.75 MB BRAM budget -> the paper spills it to DRAM (0.01x row); "
          "our energy model charges it HBM traffic the same way.")


if __name__ == "__main__":
    main()
