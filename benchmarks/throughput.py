"""Throughput sweep — the compiled-batched engine against the per-sample
seed path, batch {1, 8, 32} x backend {cpu, flex, accel}, all use cases.

For every (model, backend, batch) cell this measures the steady-state
samples/s of the staged execution plan (core/plan.py): the plan is
compiled once, then timed over repeated calls — exactly the paper's
serving regime, where compilation (the bitstream) is paid offline. Two
reference columns anchor each cell:

* ``speedup_vs_cpu``        — against the cpu backend at batch 1 (the
                              paper's ARM-CPU "1x" baseline), and
* ``speedup_vs_per_sample`` — against a loop of single-sample
                              ``Engine.run`` calls on the SAME backend
                              (the seed engine's serving pattern).

J/inference comes from core/energy.py's measured-host accounting
(HOST_POWER_BUSY x latency). A ``tuned`` column (wall-clock + modeled
latency of the autotuned twin engine, DESIGN.md §11) sits next to every
flex/accel cell so the perf trajectory records default-vs-autotuned side
by side. Results land in BENCH_throughput.json so the trajectory is
tracked across PRs. NB: on this host the accel
backend runs Pallas in interpret mode — its absolute numbers measure the
emulation, not the MXU; the batched-vs-per-sample ratio is still the
honest staging-overhead signal.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro.core.energy import HOST_POWER_BUSY, steady_state_overlap
from repro.core.engine import Engine
from repro.models import SPACE_MODELS

BATCHES = (1, 8, 32)
BACKENDS = ("cpu", "flex", "accel")
OUT_PATH = "BENCH_throughput.json"
# time budget per cell; cpu-backend cells of the conv models are the slow
# ones and get a single repeat
MIN_SECONDS = 0.25
MAX_REPEATS = 30


def _time_call(fn, min_s: float = MIN_SECONDS, max_reps: int = MAX_REPEATS,
               warmup: bool = True) -> float:
    if warmup:                                   # absorb compile/first-touch
        jax.block_until_ready(fn())
    reps, total = 0, 0.0
    while reps < 1 or (total < min_s and reps < max_reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        total += time.perf_counter() - t0
        reps += 1
    return total / reps


def bench_model(name: str, batches=BATCHES, backends=BACKENDS) -> List[Dict]:
    m = SPACE_MODELS[name]
    g = m.build_graph()
    engine = Engine(g, m.init_params(jax.random.PRNGKey(42)))
    engine.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                      for i in range(4)])
    # the autotuned twin (same params + calibration): its wall clock and
    # modeled latency land in the `tuned` columns so the perf trajectory
    # records default-vs-autotuned side by side across PRs
    tuned_engine = Engine(m.build_graph(),
                          m.init_params(jax.random.PRNGKey(42)),
                          autotune=True)
    tuned_engine.share_calibration(engine)
    rows: List[Dict] = []

    per_sample_fps: Dict[str, float] = {}
    sample = m.synthetic_input(jax.random.PRNGKey(7))
    rng = jax.random.PRNGKey(0)
    for backend in backends:
        # the seed engine's serving pattern: one sample per call
        t = _time_call(lambda: engine.run(sample, backend, rng),
                       max_reps=4 if backend == "cpu" else MAX_REPEATS,
                       warmup=backend != "cpu")
        per_sample_fps[backend] = 1.0 / t

    cpu_baseline = per_sample_fps["cpu"]
    for backend in backends:
        for batch in batches:
            inputs = m.synthetic_batch(jax.random.PRNGKey(9), batch)
            rngs = jax.random.split(jax.random.PRNGKey(3), batch)
            plan = engine.compile(backend, batch)
            staged = {k: jax.device_put(v) for k, v in inputs.items()}
            t = _time_call(lambda: plan(staged, rngs),
                           max_reps=2 if backend == "cpu" else MAX_REPEATS,
                           warmup=backend != "cpu")
            fps = batch / t
            tuned_fps = None
            tuned_modeled_ms = None
            modeled_ms = None
            if backend != "cpu":        # cpu = the eager baseline, untuned
                tplan = tuned_engine.compile(backend, batch)
                tt = _time_call(lambda: tplan(staged, rngs))
                tuned_fps = batch / tt
                tuned_modeled_ms = tplan.cost.latency_s * 1e3
                # the default baseline goes through the SAME kernel-level
                # pricer as the tuned number — the coarse roofline has
                # no tile notion, and mixing the two models would
                # corrupt any default-vs-tuned ratio off this trajectory
                ep = tuned_engine.planned(backend)
                modeled_ms = ep.default_cost_signature(
                    batch).latency_s * 1e3
            rows.append({
                "model": name,
                "backend": backend,
                "batch": batch,
                "samples_per_s": fps,
                "latency_per_sample_ms": 1e3 / fps,
                "speedup_vs_cpu": fps / cpu_baseline,
                "speedup_vs_per_sample": fps / per_sample_fps[backend],
                "j_per_inference": HOST_POWER_BUSY / fps,
                "plan_traces": getattr(plan, "n_traces", 0),
                "tuned_samples_per_s": tuned_fps,
                "modeled_latency_ms": modeled_ms,
                "tuned_modeled_latency_ms": tuned_modeled_ms,
                # the pipelined runtime's modeled columns (DESIGN.md §12):
                # steady-state per-batch interval (longest stage of the
                # plan's stage decomposition) and the effective-throughput
                # gain of overlapping staging/compute/readback
                "pipelined_modeled_latency_ms":
                    plan.cost.pipelined_latency_s * 1e3,
                "pipelined_modeled_overlap_x":
                    steady_state_overlap(plan.stages),
            })
            r = rows[-1]
            tuned_col = (f"tuned={tuned_fps:10.1f}" if tuned_fps
                         else " " * 16)
            print(f"  {name:18s} {backend:5s} B={batch:<3d} "
                  f"{fps:10.1f} samp/s  {tuned_col}  "
                  f"x_cpu={r['speedup_vs_cpu']:8.2f}  "
                  f"x_seed={r['speedup_vs_per_sample']:6.2f}  "
                  f"J/inf={r['j_per_inference']:.3e}")
    return rows


def main(models=None, batches=BATCHES, backends=BACKENDS,
         out_path: str = OUT_PATH) -> List[Dict]:
    print("== Throughput: compiled-batched plans vs per-sample seed path ==")
    all_rows: List[Dict] = []
    for name in (models or SPACE_MODELS):
        all_rows.extend(bench_model(name, batches, backends))
    payload = {
        "host_power_w": HOST_POWER_BUSY,
        "note": ("accel runs Pallas interpret-mode on this host; "
                 "speedup_vs_per_sample compares against looped "
                 "single-sample Engine.run on the same backend"),
        "rows": all_rows,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out_path} ({len(all_rows)} rows)")
    return all_rows


if __name__ == "__main__":
    main()
