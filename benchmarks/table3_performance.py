"""Paper Table III — speedup / FPS / throughput / power / energy per model.

Two honest result sets, never conflated (DESIGN.md §2):

* **measured-host** — wall-clock per-inference latency of the three
  backends on THIS host. ``cpu`` (un-jitted fp32) is the 1x baseline, as
  the paper's ARM A53 is; ``flex`` is the jitted fp32 path (HLS analog);
  ``accel`` is the INT8 Pallas path (DPU analog; interpret-mode on CPU, so
  its *measured* time is not meaningful — we report it for completeness
  but mark it interpreted).
* **modeled-TPU / modeled-ZCU104** — the analytic roofline+energy model
  (core/energy.py) with public hardware constants; the ZCU104 columns
  reproduce the paper's Table III structure (CPU vs DPU vs HLS,
  E = P x t, BaselineNet's DRAM spill).

Also measures the two fidelity properties the paper reports:
  * flex-vs-cpu max |delta| (paper: <=1e-10 for the HLS path), and
  * accel-vs-flex PTQ degradation (paper: "noticeable; QAT could mitigate").
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import (TPU_V5E, ZCU104_CPU, ZCU104_DPU,
                               ZCU104_HLS_NAIVE, measured_report, model_graph)
from repro.core.engine import Engine
from repro.models import SPACE_MODELS

REPEATS = {"cpu": 3, "flex": 30, "accel": 3}


def _time_backend(engine: Engine, inputs, backend: str) -> float:
    rng = jax.random.PRNGKey(0)
    out = engine.run(inputs, backend, rng)          # warmup / compile
    jax.block_until_ready(out)
    n = REPEATS[backend]
    t0 = time.perf_counter()
    for _ in range(n):
        out = engine.run(inputs, backend, rng)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def _max_delta(a: Dict, b: Dict) -> float:
    d = 0.0
    for k in a:
        d = max(d, float(jnp.max(jnp.abs(
            jnp.asarray(a[k], jnp.float32) - jnp.asarray(b[k], jnp.float32)))))
    return d


def run_model(name: str, skip_cpu_over_mops: float = 2000.0):
    m = SPACE_MODELS[name]
    g = m.build_graph()
    key = jax.random.PRNGKey(42)
    params = m.init_params(key)
    engine = Engine(g, params)
    inputs = m.synthetic_input(jax.random.PRNGKey(7))
    engine.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                      for i in range(4)])

    res: Dict[str, Dict] = {"model": name}

    # -- measured-host ------------------------------------------------------
    lat = {}
    for backend in ("cpu", "flex", "accel"):
        lat[backend] = _time_backend(engine, inputs, backend)
    res["host"] = {b: measured_report(name, b, t, g.n_ops).__dict__
                   for b, t in lat.items()}
    res["host_speedup_flex"] = lat["cpu"] / lat["flex"]
    res["host_speedup_accel"] = lat["cpu"] / lat["accel"]

    # -- fidelity ------------------------------------------------------------
    rng = jax.random.PRNGKey(0)
    out_cpu = engine.run(inputs, "cpu", rng)
    out_flex = engine.run(inputs, "flex", rng)
    out_accel = engine.run(inputs, "accel", rng)
    res["fidelity_flex_vs_cpu"] = _max_delta(out_cpu, out_flex)
    res["ptq_err_accel_vs_flex"] = _max_delta(out_flex, out_accel)

    # -- modeled -------------------------------------------------------------
    res["model_tpu_flex"] = model_graph(g, TPU_V5E, "flex").__dict__
    res["model_tpu_accel"] = model_graph(g, TPU_V5E, "accel").__dict__
    if m.paper_toolchain == "vitis_ai":
        acc_hw, acc_backend = ZCU104_DPU, "accel"
    else:
        acc_hw, acc_backend = ZCU104_HLS_NAIVE, "flex"
    res["model_zcu_accel"] = model_graph(g, acc_hw, acc_backend).__dict__
    res["model_zcu_fps"] = res["model_zcu_accel"]["fps"]

    # paper-accounting cross-check: with the paper's own CPU FPS as the 1x
    # baseline (A53+PyTorch dispatch overheads are not modelable), does our
    # modeled accelerator latency reproduce the paper's speedup and
    # E = P x t energy?
    p = PAPER[name]
    res["xcheck_speedup"] = res["model_zcu_fps"] / p["cpu_fps"]
    res["xcheck_energy_mj"] = (acc_hw.power_busy
                               / res["model_zcu_fps"] * 1e3)
    return res


# paper Table III ground truth for the cross-check columns
PAPER = {
    "vae_encoder": {"speedup": 24.06, "fps": 606.65, "cpu_fps": 25.21,
                    "energy_mj": 9.48},
    "cnet_plus_scalar": {"speedup": 34.16, "fps": 163.51, "cpu_fps": 4.79,
                         "energy_mj": 41.28},
    "multi_esperta": {"speedup": 5.33, "fps": 37231, "cpu_fps": 6932,
                      "energy_mj": 0.04},
    "logistic_net": {"speedup": 2.03, "fps": 646, "cpu_fps": 319,
                     "energy_mj": 2.71},
    "reduced_net": {"speedup": 0.16, "fps": 30, "cpu_fps": 186,
                    "energy_mj": 49.73},
    "baseline_net": {"speedup": 0.01, "fps": 0.21, "cpu_fps": 42,
                     "energy_mj": 8467.82},
}


def main() -> None:
    print("== Table III: performance & energy (host-measured + modeled) ==")
    hdr = (f"{'model':18s} {'cpu ms':>8s} {'flex ms':>8s} {'x(flex)':>7s} "
           f"{'fid':>8s} {'ptq':>8s} | {'TPUfps':>12s} | "
           f"{'ZCUfps':>9s} {'paper':>9s} {'ZCUx':>6s} {'paperx':>6s} "
           f"{'mJ':>8s} {'papermJ':>8s}")
    print(hdr)
    for name in SPACE_MODELS:
        r = run_model(name)
        p = PAPER[name]
        print(f"{r['model']:18s} "
              f"{r['host']['cpu']['latency_s']*1e3:8.2f} "
              f"{r['host']['flex']['latency_s']*1e3:8.2f} "
              f"{r['host_speedup_flex']:7.2f} "
              f"{r['fidelity_flex_vs_cpu']:8.1e} "
              f"{r['ptq_err_accel_vs_flex']:8.1e} | "
              f"{r['model_tpu_accel']['fps']:12.1f} | "
              f"{r['model_zcu_fps']:9.1f} {p['fps']:9.1f} "
              f"{r['xcheck_speedup']:6.2f} {p['speedup']:6.2f} "
              f"{r['xcheck_energy_mj']:8.3f} {p['energy_mj']:8.2f}")
    print("\nnotes: 'fid' = flex-vs-cpu max|delta| (paper: <=1e-10); "
          "'ptq' = INT8 PTQ output error (paper: 'noticeable'); "
          "ZCUfps/ZCUx/mJ = modeled ZCU104 accelerator (DPU util=12.5% | "
          "naive 20 MOP/s HLS) against the paper's measured columns, with "
          "the paper's CPU FPS as the 1x baseline; accel host time is "
          "interpret-mode (correctness only).")


if __name__ == "__main__":
    main()
