"""Roofline analysis — deliverable (g).

Reads benchmarks/dryrun_ledger.json (written by repro.launch.dryrun) and
derives, per (arch x shape) cell on the single-pod mesh, the three roofline
terms:

    compute    = HLO_FLOPs   / (chips x peak_FLOP/s)
    memory     = HLO_bytes   / (chips x HBM_bw)
    collective = coll_bytes  / (chips x link_bw)

with the **scan correction**: the models scan layer groups, so the full
program's cost_analysis counts the scan body once. We combine

    corrected = full + (n_groups - 1) x group_probe
                (+ (n_tail - 1) x tail_probe for zamba2's tail scan)

where group/tail probes are separate lower+compile records
(``--granularity group|tail``). All quantities in the ledger are
*per-device* (post-SPMD partitioning), so terms divide by per-chip peaks.

Also reported: MODEL_FLOPS = 6ND (dense) / 6·N_active·D (MoE) for train
(2ND fwd-only for prefill, 2·N·1·B for decode), the usefulness ratio
MODEL_FLOPS / HLO_FLOPs (catches remat/padding waste), the dominant term,
and a one-line "what would move it" note.

Usage::

    PYTHONPATH=src python -m benchmarks.roofline [--tag baseline] [--md]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict, Optional

# TPU v5e per-chip constants (assignment-given)
PEAK_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9              # B/s
ICI_BW = 50e9               # B/s per link
CHIPS_SINGLE = 256

LEDGER = os.path.join(os.path.dirname(__file__), "dryrun_ledger.json")

# group layout per arch: (n_groups, n_tail) — must match model.group_layout
_LAYOUT = None


def _layouts() -> Dict[str, tuple]:
    global _LAYOUT
    if _LAYOUT is None:
        from repro.configs import all_archs, get_arch
        from repro.nn.model import group_layout
        _LAYOUT = {}
        for a in all_archs():
            cfg = get_arch(a)
            n_groups, _, tail = group_layout(cfg)
            _LAYOUT[a] = (n_groups, tail)
    return _LAYOUT


def _model_flops(arch: str, shape_name: str) -> float:
    """Useful model FLOPs for the cell (paper-style accounting)."""
    from repro.configs import SHAPES_BY_NAME, get_arch
    cfg = get_arch(arch)
    shape = SHAPES_BY_NAME[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    # decode: one token per sequence + attention reads over the cache
    flops = 2.0 * n_active * shape.global_batch
    if cfg.attends:
        # 2 (QK) + 2 (PV) MACs per cached position per q head per head_dim
        from repro.nn.dims import compute_dims
        dims = compute_dims(cfg, tp=16)
        attn = (4.0 * shape.seq_len * dims.num_heads * dims.head_dim
                * cfg.num_attn_layers() * shape.global_batch)
        flops += attn
    return flops


def corrected_cell(ledger: Dict[str, Any], tag: str, arch: str,
                   shape: str, mesh: str = "single") -> Optional[Dict[str, Any]]:
    """Scan-corrected per-device flops / bytes / collective bytes."""
    full = ledger.get(f"{tag}/{arch}/{shape}/{mesh}")
    if not full or full.get("status") != "ok":
        return None
    grp = ledger.get(f"{tag}-group/{arch}/{shape}/{mesh}")
    tail_rec = ledger.get(f"{tag}-tail/{arch}/{shape}/{mesh}")
    n_groups, n_tail = _layouts()[arch]

    def field(rec, path, default=0.0):
        cur = rec
        for p in path:
            if cur is None:
                return default
            cur = cur.get(p)
        return default if cur is None else float(cur)

    out = {
        "flops": field(full, ("cost", "flops")),
        "bytes": field(full, ("cost", "bytes accessed")),
        "coll": field(full, ("collectives", "total")),
        "coll_by_kind": {k: v for k, v in full.get("collectives", {}).items()
                         if k != "total"},
        "scan_corrected": False,
    }
    if grp and grp.get("status") == "ok":
        k = n_groups - 1
        out["flops"] += k * field(grp, ("cost", "flops"))
        out["bytes"] += k * field(grp, ("cost", "bytes accessed"))
        out["coll"] += k * field(grp, ("collectives", "total"))
        for kind, v in grp.get("collectives", {}).items():
            if kind != "total":
                out["coll_by_kind"][kind] = (
                    out["coll_by_kind"].get(kind, 0.0) + k * float(v))
        out["scan_corrected"] = True
    if tail_rec and tail_rec.get("status") == "ok" and n_tail > 1:
        k = n_tail - 1
        out["flops"] += k * field(tail_rec, ("cost", "flops"))
        out["bytes"] += k * field(tail_rec, ("cost", "bytes accessed"))
        out["coll"] += k * field(tail_rec, ("collectives", "total"))
    out["memory"] = dict(full.get("memory", {}))
    return out


def analyze_cell(ledger, tag, arch, shape, mesh="single") -> Optional[Dict]:
    c = corrected_cell(ledger, tag, arch, shape, mesh)
    if c is None:
        return None
    # ledger values are per-device; terms are per-chip seconds
    t_comp = c["flops"] / PEAK_BF16
    t_mem = c["bytes"] / HBM_BW
    t_coll = c["coll"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    total = max(terms.values())
    mf = _model_flops(arch, shape)
    chips = CHIPS_SINGLE
    hlo_global = c["flops"] * chips
    return {
        "arch": arch, "shape": shape, "mesh": mesh,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else float("nan"),
        # roofline fraction: useful-compute time over the bounding term
        "roofline_frac": (mf / chips / PEAK_BF16) / total if total else 0.0,
        "step_time_s": total,
        "scan_corrected": c["scan_corrected"],
        "coll_by_kind": c["coll_by_kind"],
        "arg_bytes_dev": c["memory"].get("argument_size_in_bytes", 0),
        "temp_bytes_dev": c["memory"].get("temp_size_in_bytes", 0),
    }


MOVE_NOTES = {
    "compute": "compute-bound: raise MXU utilization (larger per-chip tiles, "
               "less remat recompute, int8/bf16 mixed precision)",
    "memory": "HBM-bound: cut activation traffic (fusion, flash attention, "
              "smaller remat policy) or cast residuals to bf16",
    "collective": "ICI-bound: reshard to cut all-gathers (2D sharding, "
                  "overlap collectives with compute, gradient compression)",
}


def run(tag: str = "baseline", md: bool = False, mesh: str = "single"):
    with open(LEDGER) as f:
        ledger = json.load(f)
    from repro.configs import all_archs, get_arch, shapes_for
    rows = []
    for arch in all_archs():
        for shape in shapes_for(get_arch(arch)):
            r = analyze_cell(ledger, tag, arch, shape.name, mesh)
            if r:
                rows.append(r)
    if md:
        print(f"| arch | shape | compute s | memory s | collective s | "
              f"dominant | MODEL_FLOPS | useful | roofline |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
                  f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
                  f"{r['dominant']} | {r['model_flops']:.3g} | "
                  f"{r['useful_ratio']:.2f} | {r['roofline_frac']*100:.1f}% |")
    else:
        hdr = (f"{'arch':26s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
               f"{'coll_s':>9s} {'dom':>10s} {'useful':>7s} {'roofl':>7s}")
        print(hdr)
        print("-" * len(hdr))
        for r in rows:
            print(f"{r['arch']:26s} {r['shape']:12s} {r['t_compute_s']:9.4f} "
                  f"{r['t_memory_s']:9.4f} {r['t_collective_s']:9.4f} "
                  f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} "
                  f"{r['roofline_frac']*100:6.1f}%")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    run(args.tag, args.md, args.mesh)


if __name__ == "__main__":
    main()
