"""Pipelined-runtime benchmark — async staging/compute/readback overlap
(DESIGN.md §12), gated -> BENCH_pipeline.json.

Three parts:

1. **Overlap table** (machine-independent): for every space model x
   backend {flex, accel} x rung {1, 32}, the plan's stage decomposition
   (`ExecutionPlan.stage_costs`) and its steady-state overlap — serial
   per-batch seconds / longest stage, the asymptotic effective-throughput
   gain of pipelining a saturated stream. Gates: overlap >= 1.3x on at
   least two conv-heavy models at rung 32, every chain's longest stage
   equals the signature's ``pipelined_latency_s``, and overlap >= 1
   everywhere.
2. **Identity** (machine-independent under the modeled clock): the
   scheduler with ``pipeline=True`` is dispatch-for-dispatch and
   BIT-identical to ``pipeline=False`` (records, completion timestamps,
   outputs) over a bursty two-model trace, and the overlap ledger's
   invariants hold (speedup >= 1, pipelined span <= serial span,
   per-resource occupancy <= 1).
3. **Wall-clock** (host-dependent, skipped in --smoke):
   ``ServingPipeline.run(pipeline=True)`` vs ``pipeline=False`` as
   ALTERNATING timed blocks (the autotune benchmark's `_wall_pair`
   discipline). On this CPU-only host both paths drive the same XLA
   executables, so the honest expectation is ~1.0x with async-dispatch
   headroom — the gate is no-regression, not speedup.

    PYTHONPATH=src python -m benchmarks.pipeline            # full
    PYTHONPATH=src python -m benchmarks.pipeline --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro.core.energy import steady_state_overlap
from repro.core.engine import Engine
from repro.core.pipeline import ServingPipeline
from repro.core.scheduler import ContinuousBatchingScheduler, bursty_arrivals
from repro.models import SPACE_MODELS, synthetic_requests

OUT_PATH = "BENCH_pipeline.json"
BACKENDS = ("flex", "accel")
RUNGS = (1, 32)
N_CALIB = 2
# the tentpole gate: modeled steady-state overlap at the top rung on the
# models the paper offloads for their conv stacks (Fig 11's pipelining
# candidates — staging and readback large enough to hide compute behind)
CONV_HEAVY = ("baseline_net", "cnet_plus_scalar", "vae_encoder")
OVERLAP_X = 1.3
MIN_OVERLAPPED = 2
GATE_RUNG = 32
# identity + wall-clock run the two cheap models (accel is interpret-mode
# Pallas on hosts; conv models at rung 32 would measure the emulator)
CHEAP_MODELS = ("logistic_net", "multi_esperta")
N_REQUESTS = 40
WALL_BATCH = 16
WALL_STREAM = 256             # requests per timed block — the cheap
                              # models run tens of thousands of fps, so a
                              # short stream would sit in timer noise
WALL_REPEATS = 7              # alternating best-of blocks (_wall_pair)
WALL_TOLERANCE = 0.85         # same executables; timer/thread headroom


_ENGINES = {}


def _engines(name: str):
    if name not in _ENGINES:
        m = SPACE_MODELS[name]
        e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
        e.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                     for i in range(N_CALIB)])
        _ENGINES[name] = (m, e)
    return _ENGINES[name]


# ---------------------------------------------------------------------------
# part 1: modeled overlap table
# ---------------------------------------------------------------------------


def overlap_table() -> List[Dict]:
    rows = []
    for name in SPACE_MODELS:
        _, e = _engines(name)
        for backend in BACKENDS:
            plan = e.planned(backend)
            for rung in RUNGS:
                stages = plan.stage_costs(rung)
                sig = plan.pipelined_cost_signature(rung)
                longest = max(s.seconds for s in stages)
                rows.append({
                    "model": name, "backend": backend, "rung": rung,
                    "serial_latency_ms": sig.latency_s * 1e3,
                    "pipelined_latency_ms": sig.pipelined_latency_s * 1e3,
                    "overlap_x": steady_state_overlap(stages),
                    "longest_stage": max(stages,
                                         key=lambda s: s.seconds).name,
                    "n_stages": len(stages),
                    "stages_ms": {s.name: s.seconds * 1e3 for s in stages},
                    "longest_matches_signature": bool(
                        abs(longest - sig.pipelined_latency_s)
                        <= 1e-12 + 1e-9 * longest),
                })
    return rows


def check_overlap(rows: List[Dict]) -> Dict[str, bool]:
    print(f"\n{'model':18s} {'bkend':6s} {'rung':>4s} {'serial ms':>10s} "
          f"{'pipe ms':>10s} {'overlap':>8s}  longest stage")
    for r in rows:
        print(f"{r['model']:18s} {r['backend']:6s} {r['rung']:4d} "
              f"{r['serial_latency_ms']:10.4f} "
              f"{r['pipelined_latency_ms']:10.4f} "
              f"{r['overlap_x']:7.2f}x  {r['longest_stage']}")
    all_consistent = all(r["longest_matches_signature"] for r in rows)
    all_ge_one = all(r["overlap_x"] >= 1.0 - 1e-12 for r in rows)
    # the headline gate counts conv-heavy models at the top rung by their
    # best backend's overlap
    best = {}
    for r in rows:
        if r["model"] in CONV_HEAVY and r["rung"] == GATE_RUNG:
            best[r["model"]] = max(best.get(r["model"], 0.0),
                                   r["overlap_x"])
    n_over = sum(1 for v in best.values() if v >= OVERLAP_X)
    print(f"\n[gate] longest stage == pipelined_latency_s everywhere: "
          f"{all_consistent}")
    print(f"[gate] overlap >= 1x everywhere: {all_ge_one}")
    print(f"[gate] conv-heavy models >= {OVERLAP_X}x at rung {GATE_RUNG}: "
          f"{n_over} of {list(best)} (need >= {MIN_OVERLAPPED})")
    return {"longest_stage_matches_signature": all_consistent,
            "overlap_at_least_one": all_ge_one,
            "conv_models_overlap": n_over >= MIN_OVERLAPPED}


# ---------------------------------------------------------------------------
# part 2: pipelined == synchronous under the modeled clock
# ---------------------------------------------------------------------------


def _serve(pipeline: bool):
    sched = ContinuousBatchingScheduler(clock="modeled", pipeline=pipeline)
    trace = []
    for mi, name in enumerate(CHEAP_MODELS):
        m, e = _engines(name)
        reqs = synthetic_requests(m, N_REQUESTS, seed=5 + mi)
        sched.register(name, e, backend="flex", ladder=(1, 4, 16),
                       warmup_sample=reqs[0])
        trace += [(t, name, r) for t, r in
                  zip(bursty_arrivals(N_REQUESTS, burst_size=8, gap_s=0.02,
                                      seed=20 + mi), reqs)]
    end = sched.serve_trace(trace)
    return sched, end


def identity_check() -> Dict:
    sync_sched, sync_end = _serve(pipeline=False)
    pipe_sched, pipe_end = _serve(pipeline=True)
    same_dispatches = (pipe_sched.dispatches == sync_sched.dispatches
                       and pipe_end == sync_end)
    same_completions = len(pipe_sched.completions) == len(
        sync_sched.completions)
    bit_exact = same_completions
    for a, b in zip(pipe_sched.completions, sync_sched.completions):
        same_completions = same_completions and (
            (a.rid, a.kept, a.arrival, a.finished, a.rung, a.n_real)
            == (b.rid, b.kept, b.arrival, b.finished, b.rung, b.n_real))
        for k in b.outputs:
            bit_exact = bit_exact and np.array_equal(a.outputs[k],
                                                     b.outputs[k])
    rep = pipe_sched.overlap_report()
    ledger_ok = (rep["n_dispatches"] == len(pipe_sched.dispatches)
                 and rep["overlap_speedup_x"] >= 1.0
                 and rep["pipelined_span_s"] <= rep["serial_span_s"] + 1e-12
                 and all(v <= 1.0 + 1e-9
                         for v in rep["occupancy"].values()))
    print(f"[identity] dispatches identical:  {same_dispatches}")
    print(f"[identity] completions identical: {same_completions}")
    print(f"[identity] outputs bit-exact:     {bit_exact}")
    print(f"[identity] ledger invariants:     {ledger_ok}  "
          f"(modeled overlap x{rep['overlap_speedup_x']:.3f} over "
          f"{rep['n_dispatches']} dispatches)")
    return {"report": rep,
            "gates": {"pipelined_dispatches_identical": same_dispatches,
                      "pipelined_completions_identical": same_completions,
                      "pipelined_outputs_bit_exact": bit_exact,
                      "overlap_ledger_invariants": ledger_ok}}


# ---------------------------------------------------------------------------
# part 3: wall clock — run(pipeline=True) vs run(pipeline=False)
# ---------------------------------------------------------------------------


def _wall_pair(pipe: ServingPipeline, reqs) -> Dict:
    """Alternating timed blocks of full `run()` streams, best-of per
    column (the autotune benchmark's discipline): host-load drift on this
    shared box hits both columns equally, and a 64-request stream keeps
    each block well out of single-call timer noise."""
    for p in (False, True):                     # warm both paths
        pipe.run(reqs, pipeline=p)
    best = {False: float("inf"), True: float("inf")}
    for _ in range(WALL_REPEATS):
        for p in (False, True):
            t0 = time.perf_counter()
            pipe.run(reqs, pipeline=p)
            best[p] = min(best[p], time.perf_counter() - t0)
    return {"serial_fps": len(reqs) / best[False],
            "pipelined_fps": len(reqs) / best[True],
            "ratio": best[False] / best[True]}


def wall_clock() -> Dict:
    res = {}
    for name in CHEAP_MODELS:
        m, e = _engines(name)
        reqs = synthetic_requests(m, WALL_STREAM, seed=13)
        pipe = ServingPipeline(e, backend="flex", batch_size=WALL_BATCH)
        r = _wall_pair(pipe, reqs)
        r["ok"] = r["ratio"] >= WALL_TOLERANCE
        res[name] = r
        print(f"[wall] {name:18s} flex b{WALL_BATCH}: pipelined "
              f"{r['pipelined_fps']:9.2f} fps vs serial "
              f"{r['serial_fps']:9.2f} fps (x{r['ratio']:.3f})")
    return res


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="machine-independent gates only (skip wall-clock)")
    args = ap.parse_args(argv)

    print("== pipelined runtime: modeled stage overlap + zero-drift "
          f"identity (backends {BACKENDS}, rungs {RUNGS}) ==")
    rows = overlap_table()
    gates = check_overlap(rows)
    ident = identity_check()
    gates.update(ident["gates"])
    wall = {} if args.smoke else wall_clock()
    if wall:
        gates["no_pipelined_wallclock_regression"] = all(
            w["ok"] for w in wall.values())

    with open(OUT_PATH, "w") as f:
        json.dump({"overlap_table": rows, "identity": ident["report"],
                   "wall_clock": wall, "gates": gates}, f, indent=1)
    print(f"\n[pipeline] wrote {len(rows)} overlap rows -> {OUT_PATH}")
    print("[gates] " + "  ".join(f"{k}={v}" for k, v in gates.items()))
    return 0 if all(gates.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
