"""Autotuner benchmark — plan-time tile search + prepacked weight arenas
(DESIGN.md §11), gated -> BENCH_autotune.json.

Three parts:

1. **Plan table** (machine-independent): for every space model x backend
   {flex, accel} x rung {1, 32}, the autotuned plan's modeled latency and
   J/inference against `ExecutionPlan.default_cost_signature` — the
   heuristic-default configs priced by the SAME kernel-level pricer
   (comparing against the coarse roofline would mix two models).
   Gates: tuned is never worse in any cell, and at least two
   model x rung cells improve >= 1.3x.
2. **Conformance** (machine-independent): tuned plans are bit-exact to
   untuned on flex AND accel for all six models (int8 cells exactly
   equal) — every candidate config is exact by construction (integer
   accumulation + zero padding), this pins it end-to-end.
3. **Wall-clock** (host-dependent, skipped in --smoke): tuned flex
   throughput at batch 32 must not regress vs ``autotune=False`` (the
   flex schedule configs change the MODEL only; XLA's execution is
   identical, so this must be free).

    PYTHONPATH=src python -m benchmarks.autotune            # full
    PYTHONPATH=src python -m benchmarks.autotune --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro.core.engine import Engine
from repro.models import SPACE_MODELS

OUT_PATH = "BENCH_autotune.json"
BACKENDS = ("flex", "accel")
RUNGS = (1, 32)
N_CALIB = 4
IMPROVE_X = 1.3               # required on >= MIN_IMPROVED cells
MIN_IMPROVED = 2
WALL_BATCH = 32
WALL_REPEATS = 5              # alternating best-of blocks (_wall_pair)
WALL_BLOCK_CALLS = 8          # plan calls aggregated per timed block
WALL_TOLERANCE = 0.85         # identical jitted program; timer headroom
CONFORM_N = {"flex": 4, "accel": 2}   # accel is interpret-mode on hosts


_ENGINES = {}


def _engines(name: str):
    """(model, default engine, autotuned engine) — memoized; the tuned
    engine reuses the default engine's PTQ calibration (same graph, same
    params seed) so the interpret-mode calibration cost is paid once."""
    if name not in _ENGINES:
        m = SPACE_MODELS[name]
        e0 = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
        e0.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                      for i in range(N_CALIB)])
        e1 = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)),
                    autotune=True)
        e1.share_calibration(e0)
        _ENGINES[name] = (m, e0, e1)
    return _ENGINES[name]


def plan_table() -> List[Dict]:
    rows = []
    for name in SPACE_MODELS:
        _, _, e1 = _engines(name)
        for backend in BACKENDS:
            plan = e1.planned(backend)
            for rung in RUNGS:
                tuned = plan.cost_signature(rung)
                default = plan.default_cost_signature(rung)
                rows.append({
                    "model": name, "backend": backend, "rung": rung,
                    "tuned_latency_ms": tuned.latency_s * 1e3,
                    "default_latency_ms": default.latency_s * 1e3,
                    "latency_speedup_x": (default.latency_s
                                          / max(tuned.latency_s, 1e-30)),
                    "tuned_mj_per_inf": tuned.j_per_inference * 1e3,
                    "default_mj_per_inf": default.j_per_inference * 1e3,
                    "packed_weight_bytes": sum(
                        p.packed_bytes for p in plan.packed.values()),
                })
    return rows


def check_table(rows: List[Dict]) -> Dict[str, bool]:
    print(f"\n{'model':18s} {'bkend':6s} {'rung':>4s} {'tuned ms':>11s} "
          f"{'default ms':>11s} {'x':>7s}")
    never_worse = True
    n_improved = 0
    for r in rows:
        print(f"{r['model']:18s} {r['backend']:6s} {r['rung']:4d} "
              f"{r['tuned_latency_ms']:11.4f} "
              f"{r['default_latency_ms']:11.4f} "
              f"{r['latency_speedup_x']:7.2f}")
        if r["tuned_latency_ms"] > r["default_latency_ms"] * (1 + 1e-9):
            never_worse = False
    # the >=1.3x requirement counts model x rung cells (best backend)
    cells = {}
    for r in rows:
        key = (r["model"], r["rung"])
        cells[key] = max(cells.get(key, 0.0), r["latency_speedup_x"])
    n_improved = sum(1 for v in cells.values() if v >= IMPROVE_X)
    print(f"\n[gate] tuned never worse than default: {never_worse}")
    print(f"[gate] cells >= {IMPROVE_X}x: {n_improved} "
          f"(need >= {MIN_IMPROVED})")
    return {"tuned_never_worse_than_default": never_worse,
            "min_cells_improved": n_improved >= MIN_IMPROVED}


def conformance_check() -> bool:
    ok = True
    for name in SPACE_MODELS:
        m, e0, e1 = _engines(name)
        for backend in BACKENDS:
            n = CONFORM_N[backend]
            inputs = m.synthetic_batch(jax.random.PRNGKey(99), n)
            rngs = jax.random.split(jax.random.PRNGKey(7), n)
            a = e0.run_batch(inputs, backend, rngs)
            b = e1.run_batch(inputs, backend, rngs)
            for k in a:
                same = np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
                ok = ok and same
                if not same:
                    print(f"  CONFORMANCE FAIL {name}/{backend}/{k}")
    print(f"\n[conformance] tuned == untuned (flex+accel, bit-exact): {ok}")
    return ok


def _wall_pair(e0: Engine, e1: Engine, m, batch: int):
    """Wall clock for the default and tuned engines, measured as
    ALTERNATING timed blocks of raw compiled-plan calls: the flex
    programs are identical (pinned: the tuned plan lowers to the same
    HLO), so any honest ratio is ~1.0 — alternating blocks make
    host-load drift (this is a busy shared box) hit both columns
    equally, and per-block aggregation keeps millisecond-scale calls
    out of the single-call timer-noise regime."""
    inputs = m.synthetic_batch(jax.random.PRNGKey(1), batch)
    rngs = jax.random.split(jax.random.PRNGKey(2), batch)
    staged = {k: jax.device_put(np.asarray(v, np.float32))
              for k, v in inputs.items()}
    plans = [e0.compile("flex", batch), e1.compile("flex", batch)]
    for p in plans:                             # compile + warm both
        jax.block_until_ready(p(staged, rngs))
    best = [float("inf"), float("inf")]
    for _ in range(WALL_REPEATS):
        for i, p in enumerate(plans):
            t0 = time.perf_counter()
            for _ in range(WALL_BLOCK_CALLS):
                out = p(staged, rngs)
            jax.block_until_ready(out)
            best[i] = min(best[i], time.perf_counter() - t0)
    return (batch * WALL_BLOCK_CALLS / best[0],
            batch * WALL_BLOCK_CALLS / best[1])


def wall_clock() -> Dict:
    res = {}
    for name in ("logistic_net", "vae_encoder"):
        m, e0, e1 = _engines(name)
        default_fps, tuned_fps = _wall_pair(e0, e1, m, WALL_BATCH)
        ratio = tuned_fps / default_fps
        res[name] = {"tuned_fps": tuned_fps, "default_fps": default_fps,
                     "ratio": ratio, "ok": ratio >= WALL_TOLERANCE}
        print(f"[wall] {name:18s} flex b{WALL_BATCH}: tuned "
              f"{tuned_fps:9.2f} fps vs default {default_fps:9.2f} fps "
              f"(x{ratio:.3f})")
    return res


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="machine-independent gates only (skip wall-clock)")
    args = ap.parse_args(argv)

    print("== autotuned vs heuristic-default plans "
          f"(backends {BACKENDS}, rungs {RUNGS}) ==")
    rows = plan_table()
    gates = check_table(rows)
    gates["tuned_bit_exact_flex_accel"] = conformance_check()
    wall = {} if args.smoke else wall_clock()
    if wall:
        gates["no_flex_batch32_wallclock_regression"] = all(
            w["ok"] for w in wall.values())

    stats = {name: dict(e1.tuner.stats)
             for name, (_, _, e1) in _ENGINES.items()}
    with open(OUT_PATH, "w") as f:
        json.dump({"plan_table": rows, "wall_clock": wall,
                   "tuner_stats": stats, "gates": gates}, f, indent=1)
    print(f"\n[autotune] wrote {len(rows)} plan rows -> {OUT_PATH}")
    print("[gates] " + "  ".join(f"{k}={v}" for k, v in gates.items()))
    return 0 if all(gates.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
