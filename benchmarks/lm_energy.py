"""LM serving gates — the compiled decode fast path (DESIGN.md §15).

The paper's bottom line is energy/latency per inference; the LM analog
is per generated token. This section drives the decoder-block op graph
through the SAME staged chain as the CNNs (Planned -> Lowered ->
Compiled), serves it through the prefill/decode rung ladder, and gates
the properties that make decode a scheduler-native workload:

* ``decode_vs_recompute_speedup`` — steady-state decode at batch 8 over
  the int8 KV slots must clear 3x the recompute-the-full-prefix
  baseline's tokens/s (one compiled prefill per new token — what decode
  costs WITHOUT a KV cache). Wall-clock, so measured as alternating
  best-of blocks with the benchmarks/autotune.py discipline; the 0.85
  timer-headroom tolerance folds into the 3x bar.
* ``zero_retrace_steady_decode`` / ``zero_slot_allocs_steady_decode`` —
  once a rung is warm, decode grows neither ``n_traces`` nor the KV
  slot allocator's assign count (plan-cache stats; machine-independent).
* ``kv_codes_bit_exact`` — the int8 K/V codes the prefill commit
  scattered into the slots are bit-identical to a direct host
  ``lm_quant.quantize_kv`` of the captured K/V outputs.
* ``kv_charged_to_plan`` — the KV arena shows up in the plan's
  ``CostSignature.kv_resident_bytes`` AND its ``summary()``, like
  prepacked weights.

    PYTHONPATH=src python -m benchmarks.lm_energy [--smoke]

``--smoke`` runs the machine-independent gates only.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

import jax
import numpy as np

from repro.core import energy as energy_mod
from repro.core import lm_quant
from repro.core.engine import Engine
from repro.core.lm import LMEngine
from repro.core.plan import CompiledPlan, ExecutionPlan, LoweredPlan
from repro.core.scheduler import LMRequest, LMScheduler
from repro.models import lm as lm_model

OUT_PATH = "BENCH_lm.json"
BATCH = 8                     # decode rung under test
WALL_REPEATS = 3              # alternating best-of blocks
DECODE_BLOCK = 16             # decode steps per timed block
RECOMPUTE_BLOCK = 2           # full-prefix recomputes per timed block
WALL_TOLERANCE = 0.85         # timer headroom (see autotune.py)
SPEEDUP_MIN = 3.0             # required decode-vs-recompute tokens/s x
STEADY_STEPS = 24             # decode steps in the zero-retrace window


def _build() -> LMEngine:
    cfg = lm_model.DEFAULT_CONFIG
    graph = lm_model.build_graph(cfg)
    params = lm_model.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(graph, params)
    calib = [lm_model.synthetic_input(k, cfg) for k in
             jax.random.split(jax.random.PRNGKey(1), 8)]
    engine.calibrate(calib)
    return LMEngine(engine, backend="accel", n_slots=BATCH,
                    max_new_tokens=96)


def _prompts(n: int, seed: int = 3) -> np.ndarray:
    cfg = lm_model.DEFAULT_CONFIG
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, cfg.seq_len, cfg.d_model)
                      ).astype(np.float32) * 0.5


def staged_chain_gates(lm: LMEngine, gates: Dict) -> None:
    """The decoder block compiles Planned -> Lowered -> Compiled."""
    planned = lm.engine.planned("accel")
    lowered = planned.lower(BATCH)
    compiled = lowered.compile()
    gates["compiled_staged_chain"] = (
        isinstance(planned, ExecutionPlan)
        and isinstance(lowered, LoweredPlan)
        and isinstance(compiled, CompiledPlan))
    sig = planned.cost_signature(BATCH)
    in_summary = "kv[" in planned.summary()
    gates["kv_charged_to_plan"] = (
        sig.kv_resident_bytes == float(lm.kv_plan.total_bytes)
        and lm.kv_plan.total_bytes > 0 and in_summary)
    print(f"[plan] kv_resident_bytes={sig.kv_resident_bytes:,.0f} B "
          f"({lm.kv_plan.summary().strip()})")


def steady_state_gates(lm: LMEngine, gates: Dict) -> Dict:
    """Prefill a full rung, then decode with warm programs: n_traces and
    slot assigns must not move."""
    x = _prompts(BATCH)
    slots = np.array([lm.assign_slot(rid) for rid in range(BATCH)],
                     np.int32)
    res = lm.prefill(x, slots)

    # bit-exactness: slot codes == direct host quantization of the
    # captured K/V (same compiled prefill outputs, same quantizer)
    outs = lm.engine.run_batch({"x": x}, "accel")
    ok = True
    graph = lm.plan.graph
    for n in lm._attn_nodes:
        node = graph.nodes[n]
        for which, src in (("k", node.inputs[1]), ("v", node.inputs[2])):
            codes, scale = lm_quant.quantize_kv(outs[src])
            got_c = np.asarray(lm.caches[n][f"{which}_codes"]
                               )[slots, :lm.seq_len]
            got_s = np.asarray(lm.caches[n][f"{which}_scale"]
                               )[slots, :lm.seq_len]
            ok = ok and np.array_equal(got_c, np.asarray(codes))
            ok = ok and np.array_equal(
                got_s, np.asarray(scale).astype(np.float16))
    gates["kv_codes_bit_exact"] = ok

    # warm the decode rung, then watch the counters
    res = lm.decode_step(res.hidden, slots)
    traces0, assigns0 = lm.n_traces, lm.slots.n_assigns
    for _ in range(STEADY_STEPS):
        res = lm.decode_step(res.hidden, slots)
    gates["zero_retrace_steady_decode"] = lm.n_traces == traces0
    gates["zero_slot_allocs_steady_decode"] = (
        lm.slots.n_assigns == assigns0)
    print(f"[steady] {STEADY_STEPS} decode steps: traces "
          f"{traces0}->{lm.n_traces}, slot assigns "
          f"{assigns0}->{lm.slots.n_assigns}, kv codes bit-exact={ok}")
    for rid in range(BATCH):
        lm.release_slot(rid)
    return {"traces": lm.n_traces, "slot_assigns": lm.slots.n_assigns}


def wall_decode_vs_recompute(lm: LMEngine, gates: Dict) -> Dict:
    """Alternating best-of blocks: N decode steps (8 tokens each) vs N
    full-prefix recomputes (8 tokens each — the no-KV-cache way to get
    the next token). Both arms are warm compiled programs."""
    x = _prompts(BATCH, seed=4)
    slots = np.array([lm.assign_slot(1000 + rid) for rid in range(BATCH)],
                     np.int32)
    res = lm.prefill(x, slots)          # warms the prefill rung
    res = lm.decode_step(res.hidden, slots)     # warms the decode rung
    hidden = res.hidden
    best = [float("inf"), float("inf")]
    for _ in range(WALL_REPEATS):
        # re-prefill resets the position counters so decode blocks can
        # never run past the KV capacity, whatever the repeat count
        res = lm.prefill(x, slots)
        hidden = res.hidden
        t0 = time.perf_counter()
        for _ in range(DECODE_BLOCK):
            r = lm.decode_step(hidden, slots)
            hidden = r.hidden
        best[0] = min(best[0], time.perf_counter() - t0)
        t0 = time.perf_counter()
        for _ in range(RECOMPUTE_BLOCK):
            lm.prefill(x, slots)
        best[1] = min(best[1], time.perf_counter() - t0)
    decode_tps = BATCH * DECODE_BLOCK / best[0]
    recompute_tps = BATCH * RECOMPUTE_BLOCK / best[1]
    ratio = decode_tps / recompute_tps
    gates["decode_vs_recompute_speedup"] = (
        ratio >= SPEEDUP_MIN * WALL_TOLERANCE)
    hw = energy_mod.BACKEND_HW["accel"]
    mj_tok = hw.power_busy * (best[0] / (BATCH * DECODE_BLOCK)) * 1e3
    print(f"[wall] decode b{BATCH}: {decode_tps:9.1f} tok/s vs "
          f"recompute-prefix {recompute_tps:9.1f} tok/s "
          f"(x{ratio:.1f}, gate >= {SPEEDUP_MIN}x)  "
          f"~{mj_tok:.2f} mJ/token at {hw.power_busy:.1f} W busy")
    for rid in range(BATCH):
        lm.release_slot(1000 + rid)
    return {"decode_tokens_per_s": decode_tps,
            "recompute_tokens_per_s": recompute_tps,
            "ratio": ratio, "mj_per_token_modeled": mj_tok}


def ladder_serve(lm: LMEngine) -> Dict:
    """Serve a small request stream through the LMScheduler rung ladder
    (not gated on wall time — telemetry shape only)."""
    sched = LMScheduler(lm)
    prompts = _prompts(12, seed=5)
    for rid, x in enumerate(prompts):
        sched.submit(LMRequest(rid=2000 + rid, x=x, max_new_tokens=4))
    sched.run()
    print(sched.summary())
    return sched.telemetry().to_dict()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="machine-independent gates only (skip "
                         "wall-clock)")
    args = ap.parse_args(argv)

    print("== LM serving fast path: compiled decode over int8 KV slots "
          "==")
    lm = _build()
    print(lm.plan.summary())
    gates: Dict[str, bool] = {}
    staged_chain_gates(lm, gates)
    steady = steady_state_gates(lm, gates)
    wall = {} if args.smoke else wall_decode_vs_recompute(lm, gates)
    serve = ladder_serve(lm)

    with open(OUT_PATH, "w") as f:
        json.dump({"steady": steady, "wall_clock": wall,
                   "serve_telemetry": serve, "gates": gates}, f, indent=1)
    print(f"\n[lm] wrote {OUT_PATH}")
    print("[gates] " + "  ".join(f"{k}={v}" for k, v in gates.items()))
    return 0 if all(gates.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
