"""Paper's E = P x t accounting, applied to the LM serving fleet.

The paper's bottom line is energy per inference on the accelerator; the
LM-scale analog is energy per generated token (decode) and per prefilled
request. Step times come from the roofline's dominant term (modeled TPU
v5e, scan-corrected dry-run artifacts) for BOTH the paper-faithful
baseline and the optimized (`opt`) configs, so the INT8/serving levers
show up in joules exactly the way the paper's Table III shows DPU INT8
residency.

    PYTHONPATH=src python -m benchmarks.lm_energy
"""
from __future__ import annotations

import json

from benchmarks.roofline import LEDGER, analyze_cell

CHIP_POWER_BUSY = 170.0       # W per TPU v5e chip (public board figures)
CHIPS = 256


def main() -> None:
    with open(LEDGER) as f:
        ledger = json.load(f)
    from repro.configs import SHAPES_BY_NAME, all_archs, get_arch, shapes_for

    print("== E = P x t for LM serving (modeled TPU v5e, 256 chips) ==")
    print(f"{'arch':26s} {'shape':12s} {'unit':>14s} "
          f"{'base mJ':>12s} {'opt mJ':>12s} {'x':>6s}")
    for arch in all_archs():
        for shape in shapes_for(get_arch(arch)):
            if shape.kind == "train":
                continue
            b = analyze_cell(ledger, "baseline", arch, shape.name)
            o = analyze_cell(ledger, "opt", arch, shape.name)
            if not (b and o):
                continue
            spec = SHAPES_BY_NAME[shape.name]
            if shape.kind == "decode":
                unit, n = "mJ/token", spec.global_batch
            else:
                unit, n = "mJ/request", spec.global_batch
            e_b = CHIP_POWER_BUSY * CHIPS * b["step_time_s"] / n * 1e3
            e_o = CHIP_POWER_BUSY * CHIPS * o["step_time_s"] / n * 1e3
            print(f"{arch:26s} {shape.name:12s} {unit:>14s} "
                  f"{e_b:12.2f} {e_o:12.2f} {e_b/e_o:6.1f}")
    print("\n(the same E=P*t the paper measures on the ZCU104 INT rail; "
          "t = dominant roofline term per step; energy gains mirror the "
          "paper's INT8-residency result at LM scale)")


if __name__ == "__main__":
    main()
