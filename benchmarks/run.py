"""Benchmark driver — one section per paper table / figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only table1,roofline
    PYTHONPATH=src python -m benchmarks.run --list     # what exists

Unknown section names and missing benchmark modules fail with a clear
one-line message and a non-zero exit, never a raw traceback.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

# section name -> (module, needs_dryrun_ledger, gate, description) —
# `gate` sections return an exit code that fails the driver at the end
# instead of aborting the remaining sections.
SECTIONS = {
    "table1": ("benchmarks.table1_model_stats", False, False,
               "Table I model stats: params/ops vs the paper's counts"),
    "table2": ("benchmarks.table2_footprint", False, False,
               "Table II memory footprint: fp32 vs int8 deployments"),
    "table3": ("benchmarks.table3_performance", False, False,
               "Table III latency/energy: measured host + modeled ZCU104"),
    "throughput": ("benchmarks.throughput", False, False,
                   "batched-vs-per-sample throughput per backend/rung"),
    "serving": ("benchmarks.serving_load", False, True,
                "continuous-batching serving under Poisson/burst traces"),
    "energy": ("benchmarks.energy_dispatch", False, True,
               "modeled J/inference table + envelope-constrained serving"),
    "fusion": ("benchmarks.fusion", False, True,
               "pass-pipeline gates: fused DDR bytes / J/inf vs op-by-op"),
    "autotune": ("benchmarks.autotune", False, True,
                 "autotuner gates: tuned vs heuristic tile configs, "
                 "prepacked arenas, bit-exactness"),
    "pipeline": ("benchmarks.pipeline", False, True,
                 "pipelined-runtime gates: modeled stage overlap, "
                 "pipelined==sync identity, overlap-ledger invariants"),
    "trace": ("benchmarks.trace_frontend", False, True,
              "jaxpr front-end gates: traced==hand-built structure + "
              "bit-exactness, never-hand-built demo serve"),
    "faults": ("benchmarks.faults", False, True,
               "degraded-mode gates: SEU storms detected+recovered, "
               "watchdog reboot zero-loss, inert-controller identity"),
    "radiation": ("benchmarks.radiation", False, True,
                  "orbit-aware radiation gates: sampled SAA-pass storm "
                  "recovered bit-exact, ECC/TMR regime switch, "
                  "checkpoint-cadence optimum, inert-radiation identity"),
    "table45": ("benchmarks.table45_context", False, False,
                "Tables IV/V context: device/toolchain comparison"),
    "fig_power": ("benchmarks.fig_power_phases", False, False,
                  "Figs 9-13 power-over-time serving phases"),
    "roofline": ("benchmarks.roofline", True, False,
                 "LM roofline sweep (needs the dryrun ledger)"),
    "lm": ("benchmarks.lm_energy", False, True,
           "LM serving gates: compiled decode over int8 KV slots, "
           "prefill/decode rung ladder, tokens/s vs recompute"),
}


def _load(name: str):
    module = SECTIONS[name][0]
    try:
        return importlib.import_module(module)
    except ImportError as ex:
        sys.exit(f"benchmark section {name!r} is broken: cannot import "
                 f"{module} ({ex})")


def _run_section(name: str, failures: list) -> None:
    mod = _load(name)
    _, needs_ledger, gate, _ = SECTIONS[name]
    entry = mod.run if name == "roofline" else mod.main
    if name == "roofline":
        print("== Roofline (3 terms per arch x shape, single-pod 256 "
              "chips, scan-corrected) ==")
    try:
        # gate sections take an argv list; plain sections take none
        rc = entry([]) if gate else entry()
    except FileNotFoundError:
        if needs_ledger:
            print(f"no dryrun ledger — skipping {name} (run "
                  "`PYTHONPATH=src python -m repro.launch.dryrun` first)",
                  file=sys.stderr)
            return
        raise
    if gate and rc:
        # keep running the remaining sections; fail at the end
        failures.append(f"{name} gate")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma-list of {sorted(SECTIONS)}")
    ap.add_argument("--list", action="store_true",
                    help="print available sections and exit")
    args = ap.parse_args()
    if args.list:
        width = max(len(n) for n in SECTIONS)
        for name, (_, needs_ledger, gate, desc) in SECTIONS.items():
            tags = "".join([" [gate]" if gate else "",
                            " [needs-ledger]" if needs_ledger else ""])
            print(f"{name:{width}s}  {desc}{tags}")
        return
    wanted = (list(SECTIONS) if not args.only
              else [w.strip() for w in args.only.split(",") if w.strip()])
    unknown = [w for w in wanted if w not in SECTIONS]
    if unknown:
        sys.exit(f"unknown benchmark section(s) {unknown}; choose from "
                 f"{', '.join(sorted(SECTIONS))}")

    t0 = time.time()
    failures: list = []
    for name in SECTIONS:
        if name not in wanted:
            continue
        _run_section(name, failures)
        print()
    print(f"benchmarks done in {time.time()-t0:.1f}s")
    if failures:
        sys.exit(f"failed: {', '.join(failures)}")


if __name__ == "__main__":
    main()
