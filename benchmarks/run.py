"""Benchmark driver — one section per paper table / figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only table1,roofline
"""
from __future__ import annotations

import argparse
import sys
import time

SECTIONS = ["table1", "table2", "table3", "throughput", "serving",
            "table45", "fig_power", "roofline", "lm_energy"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma-list of {SECTIONS}")
    args = ap.parse_args()
    wanted = args.only.split(",") if args.only else SECTIONS

    t0 = time.time()
    if "table1" in wanted:
        from benchmarks import table1_model_stats
        table1_model_stats.main()
        print()
    if "table2" in wanted:
        from benchmarks import table2_footprint
        table2_footprint.main()
        print()
    if "table3" in wanted:
        from benchmarks import table3_performance
        table3_performance.main()
        print()
    if "throughput" in wanted:
        from benchmarks import throughput
        throughput.main()
        print()
    failures = []
    if "serving" in wanted:
        from benchmarks import serving_load
        if serving_load.main([]):
            # keep running the remaining sections; fail at the end
            failures.append("serving_load gate")
        print()
    if "table45" in wanted:
        from benchmarks import table45_context
        table45_context.main()
        print()
    if "fig_power" in wanted:
        from benchmarks import fig_power_phases
        fig_power_phases.main()
        print()
    if "roofline" in wanted:
        from benchmarks import roofline
        print("== Roofline (3 terms per arch x shape, single-pod 256 chips, "
              "scan-corrected) ==")
        try:
            roofline.run()
        except FileNotFoundError:
            print("no dryrun_ledger.json — run "
                  "`PYTHONPATH=src python -m repro.launch.dryrun` first",
                  file=sys.stderr)
        print()
    if "lm_energy" in wanted:
        from benchmarks import lm_energy
        try:
            lm_energy.main()
        except FileNotFoundError:
            print("no dryrun ledger — skipping lm_energy", file=sys.stderr)
        print()
    print(f"benchmarks done in {time.time()-t0:.1f}s")
    if failures:
        sys.exit(f"failed: {', '.join(failures)}")


if __name__ == "__main__":
    main()
