"""Energy-dispatch benchmark — Table III's energy story, end to end
-> BENCH_energy.json.

Two parts, both machine-independent (everything is the plan-time modeled
cost; the serving part runs on the scheduler's deterministic modeled
clock):

1. **Cost table**: modeled J/inference for all six space models on
   cpu/flex/accel at every ladder rung (the plan-time cost signatures).
   Gate: at the steady-state serving rung the accel (DPU-analog) path
   uses no more energy per inference than the ARM-CPU baseline for EVERY
   model — the paper's Table III direction — and the CPU-relative energy
   ratios are reported per model.
2. **Envelope serving**: a burst trace of two co-served models dispatched
   under a 3 W sustained envelope with accel->flex->cpu fallback. The
   high-power DPU path gets duty-cycled and the dispatcher defers or
   falls back; the gates are the hard invariants: every request completes
   exactly once (no drops, no duplicates) and the envelope ledger audits
   to ZERO violations.

    PYTHONPATH=src python -m benchmarks.energy_dispatch            # full
    PYTHONPATH=src python -m benchmarks.energy_dispatch --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

import jax

from repro.core.energy import PowerEnvelope, cost_signature
from repro.core.engine import Engine
from repro.core.scheduler import ContinuousBatchingScheduler
from repro.models import SPACE_MODELS, synthetic_requests

OUT_PATH = "BENCH_energy.json"
RUNGS = (1, 4, 16, 32)
SERVE_RUNG = RUNGS[-1]                  # steady-state serving rung
SERVE_MODELS = ("logistic_net", "multi_esperta")
SERVE_BACKENDS = ("accel", "flex", "cpu")
# 3 W sustained (inside the paper's 1.5-6.75 W MPSoC span). The window is
# scaled to these models' modeled service times (ms), so the budget
# actually bites within a CI-sized trace; a flight envelope would use a
# seconds-scale window against a correspondingly longer trace.
SUSTAINED_W = 3.0
WINDOW_S = 0.001


def cost_table() -> List[Dict]:
    rows = []
    for name, m in SPACE_MODELS.items():
        g = m.build_graph()
        for backend in ("cpu", "flex", "accel"):
            for rung in RUNGS:
                sig = cost_signature(g, backend, rung)
                rows.append({
                    "model": name, "backend": backend, "rung": rung,
                    "hw": sig.hw, "flops": sig.flops,
                    "bytes_moved": sig.bytes_moved,
                    "latency_s": sig.latency_s,
                    "j_per_inference": sig.j_per_inference,
                    "power_w": sig.power_w,
                    "weights_resident": sig.weights_resident,
                })
    return rows


def check_table(rows: List[Dict]) -> Dict:
    """Gate + per-model CPU-relative energy ratios at the serving rung."""
    at = {(r["model"], r["backend"]): r for r in rows
          if r["rung"] == SERVE_RUNG}
    ratios, ok = {}, True
    print(f"\n{'model':18s} {'cpu mJ/inf':>11s} {'accel mJ/inf':>13s} "
          f"{'cpu/accel x':>12s} {'accel<=cpu':>11s}")
    for name in SPACE_MODELS:
        cpu = at[(name, "cpu")]["j_per_inference"]
        acc = at[(name, "accel")]["j_per_inference"]
        good = acc <= cpu
        ok = ok and good
        ratios[name] = {"cpu_mj": cpu * 1e3, "accel_mj": acc * 1e3,
                        "energy_reduction_x": cpu / acc,
                        "accel_le_cpu": good}
        print(f"{name:18s} {cpu*1e3:11.4f} {acc*1e3:13.4f} "
              f"{cpu/acc:12.2f} {str(good):>11s}")
    return {"serve_rung": SERVE_RUNG, "per_model": ratios,
            "accel_le_cpu_all": ok}


def serve_under_envelope(n_per_model: int) -> Dict:
    env = PowerEnvelope(SUSTAINED_W, window_s=WINDOW_S)
    sched = ContinuousBatchingScheduler(envelope=env, clock="modeled")
    trace = []
    for mi, name in enumerate(SERVE_MODELS):
        m = SPACE_MODELS[name]
        engine = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
        reqs = synthetic_requests(m, n_per_model, seed=10 + mi)
        engine.calibrate(reqs[:4])
        sched.register(name, engine, backend=SERVE_BACKENDS, ladder=RUNGS,
                       warmup_sample=reqs[0])
        # the instrument dumps its whole survey window at once: the burst
        # forces full-throttle demand, which the envelope must pace
        trace += [(0.0, name, r) for r in reqs]
    end = sched.serve_trace(trace)

    rids = [c.rid for c in sched.completions]
    n_dropped = len(trace) - len(set(rids))
    n_duplicated = len(rids) - len(set(rids))
    audit = sched.envelope_report()
    tel = {name: t.to_dict() for name, t in sched.telemetry().items()}
    print(f"\n== serving {len(trace)} burst requests under "
          f"{SUSTAINED_W} W (window {WINDOW_S*1e3:.0f} ms, modeled "
          f"clock) ==")
    print(sched.summary())
    return {
        "sustained_w": SUSTAINED_W, "window_s": WINDOW_S,
        "backends": list(SERVE_BACKENDS), "n_per_model": n_per_model,
        "virtual_end_s": end, "n_dropped": n_dropped,
        "n_duplicated": n_duplicated, "envelope_audit": audit,
        "n_deferrals": len(sched.deferrals), "telemetry": tel,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small request counts for CI")
    args = ap.parse_args(argv)
    n = 96 if args.smoke else 256

    print("== modeled energy per inference (plan-time cost signatures) ==")
    rows = cost_table()
    table_gate = check_table(rows)
    serving = serve_under_envelope(n)

    gates = {
        "accel_le_cpu_all": table_gate["accel_le_cpu_all"],
        "zero_dropped": serving["n_dropped"] == 0,
        "zero_duplicated": serving["n_duplicated"] == 0,
        "zero_envelope_violations":
            serving["envelope_audit"]["n_violations"] == 0,
    }
    with open(OUT_PATH, "w") as f:
        json.dump({"cost_table": rows, "table_gate": table_gate,
                   "serving": serving, "gates": gates}, f, indent=1)
    print(f"\n[energy_dispatch] wrote {len(rows)} cost rows -> {OUT_PATH}")
    print("[gates] " + "  ".join(f"{k}={v}" for k, v in gates.items()))
    return 0 if all(gates.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
